//! `leap::backend` — pluggable compute backends for the projection
//! kernels.
//!
//! The projector models (Siddon/Joseph/SF) describe *which* coefficients
//! a scan enumerates; a **backend** describes *how* the inner accumulation
//! loops execute them. Three slots are registered:
//!
//! * [`ScalarBackend`] — the reference tier: the original straight-line
//!   scalar loops in [`crate::projector::sf`] and
//!   [`crate::projector::plan`]. Every numerical contract in the repo is
//!   stated against this backend.
//! * [`SimdBackend`] — the throughput tier: cache-blocked, staged,
//!   lane-unrolled drivers in [`simd`] that reuse the *same* coefficient
//!   enumerators as the scalar tier (one definition of the math) but
//!   restructure the accumulation for autovectorization. See
//!   `docs/BACKENDS.md` for which paths are bit-identical to scalar and
//!   which are toleranced.
//! * [`PjrtBackend`] — a registered but non-executing slot for the
//!   AOT-compiled XLA artifacts behind the `pjrt` cargo feature
//!   ([`crate::runtime`]). Its [`Caps::projection`] is `false`, so every
//!   layer that validates backends (the [`crate::api::ScanBuilder`] knob,
//!   [`crate::projector::ProjectionPlan::lower`], the protocol-v2 session
//!   handshake) rejects it with a typed error instead of silently running
//!   scalar code — the slot proves the dispatch seam is real without
//!   pretending the engine is wired in.
//!
//! Selection is threaded through every layer: `Projector` carries a
//! [`BackendKind`] (snapshot into its plan and the plan-cache key),
//! `ScanBuilder::backend(...)`/`backend_str(...)` set it explicitly, the
//! `LEAP_BACKEND` env var sets the process default, and [`detect`] picks
//! the best executable tier for the host when neither is given. Served
//! sessions report their backend in the protocol-v2 OpenSession reply and
//! in `__stats`, so results are attributable end to end.
//!
//! **Invariants.** Within a backend, forward and back projection are
//! bit-identical across thread counts (the PR 2 slab-ownership invariant,
//! extended per backend — see [`Caps::thread_invariant`]). Across
//! backends, outputs agree to a small relative tolerance
//! (`rust/tests/backend_property.rs` sweeps all models × geometries), and
//! the matched-pair adjoint identity holds *within* each backend because
//! both directions of a backend enumerate identical coefficients.

pub mod pjrt;
pub mod scalar;
pub mod simd;

pub use pjrt::PjrtBackend;
pub use scalar::ScalarBackend;
pub use simd::SimdBackend;

use std::sync::OnceLock;

/// Identity of a compute backend — the value threaded from
/// [`crate::api::ScanBuilder`] through [`crate::projector::Projector`]
/// and its plans down to the kernel dispatch (and over the wire in the
/// protocol-v2 session meta).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Scalar,
    Simd,
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(BackendKind::Scalar),
            "simd" => Some(BackendKind::Simd),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Simd => "simd",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Capability flags a backend advertises to the validation layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Caps {
    /// Can this backend execute forward/back projection natively? When
    /// `false` the backend is a registered slot only: `ScanBuilder`,
    /// `ProjectionPlan::lower` and the session handshake reject it with
    /// a typed [`crate::api::LeapError::Unsupported`].
    pub projection: bool,
    /// Are projection outputs bit-identical across thread counts? Both
    /// executable CPU tiers guarantee this (slab-owned accumulation
    /// keeps per-voxel/per-bin summation order fixed for any worker
    /// count).
    pub thread_invariant: bool,
}

/// A compute backend: identity, lane shape and capabilities. The actual
/// kernel drivers are free functions in the per-backend modules (the
/// dispatch sites match on [`BackendKind`] directly — no virtual calls
/// inside hot loops); this trait is the *registry* surface the
/// validation, telemetry and docs layers talk to.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// SIMD lane width the backend's inner loops are shaped for
    /// (1 = scalar).
    fn lanes(&self) -> usize;

    fn caps(&self) -> Caps;
}

static SCALAR: ScalarBackend = ScalarBackend;
static SIMD: SimdBackend = SimdBackend;
static PJRT: PjrtBackend = PjrtBackend;

/// The registered backend instance for `kind`.
pub fn get(kind: BackendKind) -> &'static dyn Backend {
    match kind {
        BackendKind::Scalar => &SCALAR,
        BackendKind::Simd => &SIMD,
        BackendKind::Pjrt => &PJRT,
    }
}

/// All registered backend slots, executable or not (for telemetry and
/// docs enumeration).
pub fn all() -> [&'static dyn Backend; 3] {
    [&SCALAR, &SIMD, &PJRT]
}

/// Parse a `LEAP_BACKEND`-style override into an *executable* backend
/// kind. Lenient like `LEAP_THREADS`: unset, empty, unknown names and
/// non-executing slots (`pjrt` — which must be requested explicitly
/// through the typed [`crate::api::ScanBuilder::backend`] knob to get
/// its typed error) all return `None`, falling through to [`detect`],
/// so a stray env var can never panic process startup.
pub(crate) fn kind_from_env(raw: Option<&str>) -> Option<BackendKind> {
    let kind = BackendKind::parse(raw?.trim())?;
    if get(kind).caps().projection {
        Some(kind)
    } else {
        None
    }
}

/// Runtime detection fallback: the widest executable tier the host
/// supports. x86-64 with AVX2 and aarch64 (NEON is baseline) get the
/// SIMD tier; anything else gets the scalar reference.
pub fn detect() -> BackendKind {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return BackendKind::Simd;
        }
        BackendKind::Scalar
    }
    #[cfg(target_arch = "aarch64")]
    {
        BackendKind::Simd
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        BackendKind::Scalar
    }
}

/// The process-wide default backend: `LEAP_BACKEND` when it names an
/// executable backend, else [`detect`]. Resolved once (like the worker
/// pool's `LEAP_THREADS`) so every layer — direct projectors, the plan
/// cache, served sessions — agrees on one default. Never returns the
/// PJRT slot, so constructing a [`crate::projector::Projector`] with the
/// default can never produce an unexecutable scan.
pub fn default_kind() -> BackendKind {
    static DEFAULT: OnceLock<BackendKind> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        kind_from_env(std::env::var("LEAP_BACKEND").ok().as_deref()).unwrap_or_else(detect)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_names() {
        for kind in [BackendKind::Scalar, BackendKind::Simd, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(kind.name()), Some(kind));
            assert_eq!(get(kind).kind(), kind);
            assert_eq!(get(kind).name(), kind.name());
        }
        assert_eq!(BackendKind::parse("SIMD"), Some(BackendKind::Simd));
        assert_eq!(BackendKind::parse("warp"), None);
        assert_eq!(BackendKind::parse(""), None);
    }

    #[test]
    fn env_override_is_lenient_and_never_yields_pjrt() {
        // mirrors pool::threads_from_env: the pure helper is what we can
        // test race-free (the process env + OnceLock are global state)
        assert_eq!(kind_from_env(None), None);
        assert_eq!(kind_from_env(Some("")), None);
        assert_eq!(kind_from_env(Some("warp")), None);
        assert_eq!(kind_from_env(Some("scalar")), Some(BackendKind::Scalar));
        assert_eq!(kind_from_env(Some(" Simd ")), Some(BackendKind::Simd));
        // pjrt is a registered slot but not executable: env selection
        // falls back to detection instead of wedging every projector
        assert_eq!(kind_from_env(Some("pjrt")), None);
    }

    #[test]
    fn caps_gate_the_pjrt_slot_only() {
        assert!(get(BackendKind::Scalar).caps().projection);
        assert!(get(BackendKind::Simd).caps().projection);
        assert!(!get(BackendKind::Pjrt).caps().projection);
        // both CPU tiers keep the PR 2 thread-count invariant
        assert!(get(BackendKind::Scalar).caps().thread_invariant);
        assert!(get(BackendKind::Simd).caps().thread_invariant);
    }

    #[test]
    fn lane_widths_describe_the_tiers() {
        assert_eq!(get(BackendKind::Scalar).lanes(), 1);
        assert_eq!(get(BackendKind::Simd).lanes(), 8);
    }

    #[test]
    fn detection_and_default_are_always_executable() {
        assert!(get(detect()).caps().projection);
        assert!(get(default_kind()).caps().projection);
        // and stable across calls (OnceLock)
        assert_eq!(default_kind(), default_kind());
    }
}
