//! The SIMD throughput tier: cache-blocked, lane-unrolled drivers for the
//! SF gather/scatter hot paths.
//!
//! **One definition of the math.** These drivers do not re-derive any
//! coefficient: they replay the exact `pub(crate)` enumerators the scalar
//! tier uses ([`sf::parallel_view_coeffs_planned`],
//! [`sf::parallel_rows_coeffs`], [`sf::fan_rows_coeffs`],
//! [`sf::cone_view_coeffs_planned`], [`sf::cone_column_coeffs`]) and only
//! restructure the *accumulation*:
//!
//! * **Staged scatter/gather (bit-identical).** Forward projection stages
//!   one view's sinogram slab, and parallel/fan backprojection stages the
//!   worker's whole voxel slab **across all views**, in a zeroed local
//!   buffer, then flushes once with a lane-unrolled copy. Every target
//!   cell receives the same additions in the same order starting from the
//!   same zero as the scalar tier, so staged outputs are **bit-identical**
//!   to scalar (float addition is exact against a running sum that shares
//!   its history; the flush is a copy, not a sum). The staged slab is the
//!   cache-blocking win: the hot accumulation target stays resident
//!   instead of streaming the full output per view. Flushing the back
//!   gather per *view* would **not** be bit-identical —
//!   `(s₀+t₁)+t₂ ≠ s₀+(t₁+t₂)` — which is why the stage spans all views.
//! * **Multi-lane accumulation (toleranced).** The cone back gather and
//!   the Joseph/Siddon marching accumulation (see
//!   `plan::ray_forward_exec`) cycle each voxel's/ray's terms through 4
//!   partial sums combined pairwise at the end — the standard
//!   dependence-breaking shape that lets the compiler vectorize the
//!   reduction. The summation *tree* differs from scalar, so these paths
//!   agree with scalar only to floating-point tolerance; the term order
//!   is still fixed per voxel/ray, so results remain deterministic and
//!   bit-identical across thread counts.
//!
//! The identity-vs-tolerance policy per path is documented in
//! `docs/BACKENDS.md` and enforced by `rust/tests/backend_property.rs`
//! plus the module tests below. The ray *backprojection* scatter has no
//! safely vectorizable inner loop (indirect per-deposit writes behind a
//! slab-ownership guard), so both tiers share the scalar
//! `plan::ray_back_exec` — exact equality there is by construction.

use crate::array::{Sino, Vol3};
use crate::geometry::{ConeBeam, FanBeam, ParallelBeam, VolumeGeometry};
use crate::precision::StorageTier;
use crate::projector::sf;
use crate::util::pool::{parallel_chunks, parallel_items_with, ParWriter};

use super::{Backend, BackendKind, Caps};

/// The CPU throughput tier (f32x8-shaped inner loops).
pub struct SimdBackend;

/// Lane width the staged flushes are unrolled by — f32x8, one AVX2/NEON-
/// pair register of f32.
pub const LANES: usize = 8;

impl Backend for SimdBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Simd
    }

    fn lanes(&self) -> usize {
        LANES
    }

    fn caps(&self) -> Caps {
        Caps { projection: true, thread_invariant: true }
    }
}

/// Flush a staged accumulation buffer into the shared output at `base`
/// with an unrolled-by-[`LANES`] copy (a straight-line gather-free loop
/// the compiler turns into vector stores). The caller owns
/// `[base, base + stage.len())` exclusively, as everywhere slab
/// ownership holds.
#[inline]
fn flush_lanes(out: &ParWriter, base: usize, stage: &[f32]) {
    let n = stage.len();
    let mut i = 0usize;
    while i + LANES <= n {
        out.set(base + i, stage[i]);
        out.set(base + i + 1, stage[i + 1]);
        out.set(base + i + 2, stage[i + 2]);
        out.set(base + i + 3, stage[i + 3]);
        out.set(base + i + 4, stage[i + 4]);
        out.set(base + i + 5, stage[i + 5]);
        out.set(base + i + 6, stage[i + 6]);
        out.set(base + i + 7, stage[i + 7]);
        i += LANES;
    }
    while i < n {
        out.set(base + i, stage[i]);
        i += 1;
    }
}

/// SIMD-tier SF forward projection, parallel beam: stages each view's
/// `nrows × ncols` slab in per-worker scratch, flushes once.
/// Bit-identical to [`sf::forward_parallel`] (staged scatter — see the
/// module docs). `plans = None` plans per view on the fly exactly like
/// the scalar direct path, so planned ≡ direct holds within this backend
/// too.
pub(crate) fn forward_parallel_simd(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&sf::ParallelPlanSet>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_parallel_simd_range(vg, g, plans, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_parallel_simd`] restricted to the view range `v0..v1` — the
/// same stitching contract as `sf::forward_parallel_range` (views own
/// disjoint slabs; staging does not change the per-cell addition order).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_parallel_simd_range(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&sf::ParallelPlanSet>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert_eq!(sino.nviews, g.angles.len());
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.data[v0 * nrows * ncols..v1 * nrows * ncols].fill(0.0);
    let local_rows;
    let rows: &sf::ParallelRowWeights = match plans {
        Some(set) => &set.rows,
        None => {
            local_rows = sf::plan_parallel_rows(vg, g);
            &local_rows
        }
    };
    let slab = nrows * ncols;
    let out = ParWriter::new(&mut sino.data);
    parallel_items_with(v1 - v0, threads, Vec::new, |stage: &mut Vec<f32>, r| {
        let view = v0 + r;
        stage.clear();
        stage.resize(slab, 0.0);
        let local;
        let vp = match plans {
            Some(set) => &set.views[view],
            None => {
                local = sf::plan_parallel_view(vg, g, view);
                &local
            }
        };
        sf::parallel_view_coeffs_planned(vg, g, vp, rows, |flat, row, col, coeff| {
            stage[row * ncols + col] += (coeff as f32) * vol.data[flat];
        });
        flush_lanes(&out, view * slab, stage);
    });
}

/// SIMD-tier matched SF backprojection, parallel beam: each worker stages
/// its whole voxel slab (`rows m0..m1`) across **all** views and flushes
/// once — bit-identical to [`sf::back_parallel`] and cache-resident
/// across the view loop.
pub(crate) fn back_parallel_simd(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&sf::ParallelPlanSet>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_parallel_simd_range(vg, g, plans, sino, vol, threads, 0, vg.nz * vg.ny)
}

/// [`back_parallel_simd`] restricted to the voxel-row range `u0..u1` —
/// the same stitching contract as `sf::back_parallel_range` (every owned
/// voxel replays all views in global order).
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_parallel_simd_range(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&sf::ParallelPlanSet>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let nunits = vg.nz * vg.ny;
    assert!(u0 <= u1 && u1 <= nunits, "unit range {u0}..{u1}");
    let ncols = sino.ncols;
    vol.data[u0 * vg.nx..u1 * vg.nx].fill(0.0);
    let local_set;
    let set: &sf::ParallelPlanSet = match plans {
        Some(s) => s,
        None => {
            local_set = sf::plan_parallel_set(vg, g);
            &local_set
        }
    };
    let nx = vg.nx;
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(u1 - u0, threads, |a, b| {
        let (m0, m1) = (u0 + a, u0 + b);
        let base = m0 * nx;
        let mut stage = vec![0.0f32; (m1 - m0) * nx];
        for (view, vp) in set.views.iter().enumerate() {
            let vdata = sino.view(view);
            sf::parallel_rows_coeffs(vg, g, vp, &set.rows, m0, m1, |flat, row, col, coeff| {
                stage[flat - base] += (coeff as f32) * vdata[row * ncols + col];
            });
        }
        flush_lanes(&out, base, &stage);
    });
}

/// SIMD-tier SF forward projection, fan beam (staged per-view slab;
/// bit-identical to [`sf::forward_fan`]).
pub(crate) fn forward_fan_simd(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[sf::FanViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_fan_simd_range(vg, g, plans, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_fan_simd`] restricted to the view range `v0..v1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_fan_simd_range(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[sf::FanViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert_eq!(vg.nz, 1, "fan-beam SF requires a 2-D volume");
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let ncols = sino.ncols;
    sino.data[v0 * ncols..v1 * ncols].fill(0.0);
    let out = ParWriter::new(&mut sino.data);
    parallel_items_with(v1 - v0, threads, Vec::new, |stage: &mut Vec<f32>, r| {
        let view = v0 + r;
        stage.clear();
        stage.resize(ncols, 0.0);
        let vp = match plans {
            Some(ps) => ps[view],
            None => sf::plan_fan_view(g, view),
        };
        sf::fan_rows_coeffs(vg, g, &vp, 0, vg.ny, |flat, col, coeff| {
            stage[col] += (coeff as f32) * vol.data[flat];
        });
        flush_lanes(&out, view * ncols, stage);
    });
}

/// SIMD-tier matched SF backprojection, fan beam (whole-slab staging
/// across all views; bit-identical to [`sf::back_fan`]).
pub(crate) fn back_fan_simd(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[sf::FanViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_fan_simd_range(vg, g, plans, sino, vol, threads, 0, vg.ny)
}

/// [`back_fan_simd`] restricted to the voxel-row range `u0..u1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_fan_simd_range(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[sf::FanViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert_eq!(vg.nz, 1);
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    let nviews = g.angles.len();
    vol.data[u0 * vg.nx..u1 * vg.nx].fill(0.0);
    let local;
    let views: &[sf::FanViewPlan] = match plans {
        Some(ps) => ps,
        None => {
            local = (0..nviews).map(|v| sf::plan_fan_view(g, v)).collect::<Vec<_>>();
            &local
        }
    };
    let nx = vg.nx;
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(u1 - u0, threads, |a, b| {
        let (j0, j1) = (u0 + a, u0 + b);
        let base = j0 * nx;
        let mut stage = vec![0.0f32; (j1 - j0) * nx];
        for (view, vp) in views.iter().enumerate() {
            let vdata = sino.view(view);
            sf::fan_rows_coeffs(vg, g, vp, j0, j1, |flat, col, coeff| {
                stage[flat - base] += (coeff as f32) * vdata[col];
            });
        }
        flush_lanes(&out, base, &stage);
    });
}

/// SIMD-tier SF forward projection, cone beam (staged per-view slab;
/// bit-identical to [`sf::forward_cone`]). The per-worker scratch pairs
/// the stage buffer with the on-the-fly view plan the direct path
/// refills.
pub(crate) fn forward_cone_simd(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[sf::ConeViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_cone_simd_range(vg, g, plans, StorageTier::F32, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_cone_simd`] restricted to the view range `v0..v1`. `tier`
/// round-trips on-the-fly scratch plans through the storage tier exactly
/// like the scalar executor, so the SIMD decode path replays the same
/// quantized weights a packed cached plan stores.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_cone_simd_range(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[sf::ConeViewPlan]>,
    tier: StorageTier,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.data[v0 * nrows * ncols..v1 * nrows * ncols].fill(0.0);
    let slab = nrows * ncols;
    let out = ParWriter::new(&mut sino.data);
    parallel_items_with(
        v1 - v0,
        threads,
        || (sf::ConeViewPlan::empty(), Vec::new()),
        |scratch: &mut (sf::ConeViewPlan, Vec<f32>), r| {
            let view = v0 + r;
            let (plan_scratch, stage) = scratch;
            stage.clear();
            stage.resize(slab, 0.0);
            let vp: &sf::ConeViewPlan = match plans {
                Some(ps) => &ps[view],
                None => {
                    sf::plan_cone_rows_into(vg, g, view, 0, vg.ny, plan_scratch);
                    plan_scratch.quantize_in_place(tier);
                    plan_scratch
                }
            };
            sf::cone_view_coeffs_planned(vg, g, vp, |flat, row, col, coeff| {
                stage[row * ncols + col] += (coeff as f32) * vol.data[flat];
            });
            flush_lanes(&out, view * slab, stage);
        },
    );
}

/// SIMD-tier matched SF backprojection, cone beam. Slab-owned like the
/// scalar gather (each voxel row `j` is claimed by exactly one worker),
/// but each voxel's `(detector row × u-bin)` terms for one view cycle
/// through 4 partial sums combined pairwise before the single deposit —
/// multi-lane accumulation, **toleranced** against scalar (the summation
/// tree differs) yet deterministic: term order per voxel is fixed by the
/// enumeration, so outputs are bit-identical across thread counts.
pub(crate) fn back_cone_simd(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[sf::ConeViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_cone_simd_range(vg, g, plans, StorageTier::F32, sino, vol, threads, 0, vg.ny)
}

/// [`back_cone_simd`] restricted to the voxel-row range `u0..u1` (same
/// per-(k, j) x-row ownership as `sf::back_cone_range`; `tier` as in
/// [`forward_cone_simd_range`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_cone_simd_range(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[sf::ConeViewPlan]>,
    tier: StorageTier,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let nviews = g.angles.len();
    let ncols = sino.ncols;
    let ny = vg.ny;
    assert!(u0 <= u1 && u1 <= ny, "unit range {u0}..{u1}");
    let plane = ny * vg.nx;
    for k in 0..vg.nz {
        vol.data[k * plane + u0 * vg.nx..k * plane + u1 * vg.nx].fill(0.0);
    }
    if nviews == 0 {
        return;
    }
    let out = ParWriter::new(&mut vol.data);
    parallel_items_with(u1 - u0, threads, sf::ConeViewPlan::empty, |scratch, r| {
        let j = u0 + r;
        for view in 0..nviews {
            let (vp, j_off): (&sf::ConeViewPlan, usize) = match plans {
                Some(ps) => (&ps[view], 0),
                None => {
                    sf::plan_cone_rows_into(vg, g, view, j, j + 1, scratch);
                    scratch.quantize_in_place(tier);
                    (scratch, j)
                }
            };
            let vdata = sino.view(view);
            for i in 0..vg.nx {
                let f = vp.foot[(j - j_off) * vg.nx + i];
                let u_bins = vp.u_bins(&f);
                // one accumulator block per target voxel: the enumeration
                // emits a column's coefficients grouped by flat index
                // (z-slice outer loop), so a flat change is a voxel change
                let mut cur = usize::MAX;
                let mut acc = [0.0f32; 4];
                let mut lane = 0usize;
                sf::cone_column_coeffs(vg, g, &f, u_bins, plane, j * vg.nx + i, |flat, row, col, coeff| {
                    if flat != cur {
                        if cur != usize::MAX {
                            out.add(cur, (acc[0] + acc[2]) + (acc[1] + acc[3]));
                        }
                        cur = flat;
                        acc = [0.0; 4];
                        lane = 0;
                    }
                    acc[lane & 3] += (coeff as f32) * vdata[row * ncols + col];
                    lane += 1;
                });
                if cur != usize::MAX {
                    out.add(cur, (acc[0] + acc[2]) + (acc[1] + acc[3]));
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::DetectorShape;
    use crate::util::rng::Rng;

    fn rand_vol(vg: &VolumeGeometry, seed: u64) -> Vol3 {
        let mut v = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        Rng::new(seed).fill_uniform(&mut v.data, 0.0, 1.0);
        v
    }

    fn rand_sino(nviews: usize, nrows: usize, ncols: usize, seed: u64) -> Sino {
        let mut s = Sino::zeros(nviews, nrows, ncols);
        Rng::new(seed).fill_uniform(&mut s.data, -1.0, 1.0);
        s
    }

    #[test]
    fn parallel_staged_paths_are_bit_identical_to_scalar() {
        let vg = VolumeGeometry { nx: 9, ny: 7, nz: 4, vx: 1.1, vy: 0.9, vz: 1.3, cx: 0.4, cy: -0.2, cz: 0.1 };
        let g = ParallelBeam::standard_3d(5, 6, 14, 1.2, 1.1);
        let vol = rand_vol(&vg, 3);
        let sino_in = rand_sino(5, 6, 14, 4);
        let set = sf::plan_parallel_set(&vg, &g);
        for threads in [1usize, 3] {
            for plans in [None, Some(&set)] {
                let mut a = Sino::zeros(5, 6, 14);
                let mut b = Sino::zeros(5, 6, 14);
                sf::forward_parallel_opt(&vg, &g, plans, &vol, &mut a, threads);
                forward_parallel_simd(&vg, &g, plans, &vol, &mut b, threads);
                assert_eq!(a.data, b.data, "forward, threads {threads}");
                let mut va = Vol3::zeros(vg.nx, vg.ny, vg.nz);
                let mut vb = Vol3::zeros(vg.nx, vg.ny, vg.nz);
                sf::back_parallel_opt(&vg, &g, plans, &sino_in, &mut va, threads);
                back_parallel_simd(&vg, &g, plans, &sino_in, &mut vb, threads);
                assert_eq!(va.data, vb.data, "back, threads {threads}");
            }
        }
    }

    #[test]
    fn fan_staged_paths_are_bit_identical_to_scalar() {
        let vg = VolumeGeometry::slice2d(12, 10, 1.0);
        let g = FanBeam::standard(5, 16, 1.2, 55.0, 110.0);
        let vol = rand_vol(&vg, 7);
        let sino_in = rand_sino(5, 1, 16, 8);
        let plans: Vec<sf::FanViewPlan> = (0..5).map(|v| sf::plan_fan_view(&g, v)).collect();
        for threads in [1usize, 4] {
            for p in [None, Some(plans.as_slice())] {
                let mut a = Sino::zeros2d(5, 16);
                let mut b = Sino::zeros2d(5, 16);
                sf::forward_fan_opt(&vg, &g, p, &vol, &mut a, threads);
                forward_fan_simd(&vg, &g, p, &vol, &mut b, threads);
                assert_eq!(a.data, b.data, "forward, threads {threads}");
                let mut va = Vol3::zeros2d(12, 10);
                let mut vb = Vol3::zeros2d(12, 10);
                sf::back_fan_opt(&vg, &g, p, &sino_in, &mut va, threads);
                back_fan_simd(&vg, &g, p, &sino_in, &mut vb, threads);
                assert_eq!(va.data, vb.data, "back, threads {threads}");
            }
        }
    }

    #[test]
    fn cone_forward_is_bit_identical_and_back_is_toleranced() {
        let vg = VolumeGeometry::cube(8, 1.0);
        for shape in [DetectorShape::Flat, DetectorShape::Curved] {
            let mut g = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
            g.shape = shape;
            let vol = rand_vol(&vg, 11);
            let sino_in = rand_sino(5, 6, 10, 12);
            let plans: Vec<sf::ConeViewPlan> =
                (0..5).map(|v| sf::plan_cone_view(&vg, &g, v)).collect();
            for p in [None, Some(plans.as_slice())] {
                let mut a = Sino::zeros(5, 6, 10);
                let mut b = Sino::zeros(5, 6, 10);
                sf::forward_cone_opt(&vg, &g, p, &vol, &mut a, 2);
                forward_cone_simd(&vg, &g, p, &vol, &mut b, 2);
                assert_eq!(a.data, b.data, "forward {shape:?}");
                // back: multi-lane accumulation changes the summation
                // tree — toleranced, not bit-identical
                let mut va = Vol3::zeros(8, 8, 8);
                let mut vb = Vol3::zeros(8, 8, 8);
                sf::back_cone_opt(&vg, &g, p, &sino_in, &mut va, 2);
                back_cone_simd(&vg, &g, p, &sino_in, &mut vb, 2);
                let err = crate::util::rel_l2(&vb.data, &va.data, 1e-12);
                assert!(err < 1e-6, "back {shape:?}: rel err {err}");
            }
        }
    }

    #[test]
    fn cone_back_is_bit_identical_across_thread_counts() {
        // toleranced vs scalar, but the PR 2 invariant must still hold
        // *within* the backend: deterministic per-voxel term order for
        // any worker count
        let vg = VolumeGeometry::cube(8, 1.0);
        let g = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let sino_in = rand_sino(5, 6, 10, 21);
        let mut reference = Vol3::zeros(8, 8, 8);
        back_cone_simd(&vg, &g, None, &sino_in, &mut reference, 1);
        for threads in [2usize, 4, 7] {
            let mut v = Vol3::zeros(8, 8, 8);
            back_cone_simd(&vg, &g, None, &sino_in, &mut v, threads);
            assert_eq!(reference.data, v.data, "threads {threads}");
        }
    }
}
