//! The PJRT backend slot — registered, capability-gated, non-executing.
//!
//! [`crate::runtime`] holds the PJRT engine for the AOT-compiled
//! JAX/Pallas artifacts, gated behind the `pjrt` cargo feature (without
//! it, a clear-error stub with the same API). This module registers that
//! engine as a *backend slot* so the dispatch seam introduced by
//! [`crate::backend`] demonstrably extends past the two CPU tiers:
//! [`Caps::projection`] is `false`, so every validated entry point —
//! [`crate::api::ScanBuilder::backend`],
//! [`crate::projector::ProjectionPlan::lower`], the protocol-v2 session
//! handshake — turns a PJRT selection into a typed
//! [`crate::api::LeapError::Unsupported`] naming the missing feature,
//! and the kernel-layer dispatch treats it as unreachable (the gates run
//! first on every path that can construct a projector).
//!
//! Wiring the engine in for real means flipping `projection` to `true`
//! and adding drivers that stage volumes through
//! [`crate::runtime::Engine`] — the registry, selection plumbing, wire
//! reporting and tests are already backend-agnostic (see
//! `docs/BACKENDS.md` §"Adding a backend").

use super::{Backend, BackendKind, Caps};

/// The feature-gated PJRT slot: selectable by name everywhere, executable
/// nowhere (yet).
pub struct PjrtBackend;

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    /// Device-dependent; the slot advertises no CPU lane shape.
    fn lanes(&self) -> usize {
        1
    }

    fn caps(&self) -> Caps {
        Caps { projection: false, thread_invariant: false }
    }
}
