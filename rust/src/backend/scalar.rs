//! The scalar reference backend — today's kernels, registered as a tier.
//!
//! This backend has no drivers of its own: selecting it dispatches to the
//! original straight-line loops in [`crate::projector::sf`] (SF parallel/
//! fan/cone scatter and slab-owned gather) and
//! [`crate::projector::plan`] (`ray_forward_exec`/`ray_back_exec` for
//! Siddon/Joseph and the modular-beam fallback). Every numerical contract
//! in the repo — matched-pair adjoint identity, planned ≡ direct
//! bit-identity, thread-count invariance, the analytic-phantom accuracy
//! sweeps — is stated against these loops, which is why they stay the
//! *reference* implementation the SIMD tier is checked against
//! (`rust/tests/backend_property.rs`).

use super::{Backend, BackendKind, Caps};

/// The reference CPU tier (lane width 1).
pub struct ScalarBackend;

impl Backend for ScalarBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Scalar
    }

    fn lanes(&self) -> usize {
        1
    }

    fn caps(&self) -> Caps {
        Caps { projection: true, thread_invariant: true }
    }
}
