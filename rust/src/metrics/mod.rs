//! Image-quality metrics used in the paper's evaluation: PSNR and SSIM
//! (Figure 3), plus RMSE/MAE and a memory-footprint model for Table 1.

use crate::array::Vol3;

/// Root-mean-square error.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len().max(1) as f64;
    let ss: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    (ss / n).sqrt()
}

/// Mean absolute error.
pub fn mae(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len().max(1) as f64;
    a.iter().zip(b.iter()).map(|(&x, &y)| ((x - y) as f64).abs()).sum::<f64>() / n
}

/// Peak signal-to-noise ratio in dB against a reference `truth`.
/// `data_range` is the peak value; pass `None` to use `max(truth)`, the
/// convention of the paper's luggage experiment.
pub fn psnr(img: &[f32], truth: &[f32], data_range: Option<f64>) -> f64 {
    let peak = data_range.unwrap_or_else(|| {
        truth.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64
    });
    let e = rmse(img, truth);
    if e == 0.0 {
        return f64::INFINITY;
    }
    20.0 * (peak / e).log10()
}

/// Gaussian-windowed SSIM (Wang et al. 2004) over a 2-D image, the metric
/// of the paper's Figure 3. `11×11` window, `σ = 1.5`, `K1 = 0.01`,
/// `K2 = 0.03`. Returns the mean SSIM map value.
pub fn ssim2d(img: &[f32], truth: &[f32], nx: usize, ny: usize, data_range: Option<f64>) -> f64 {
    assert_eq!(img.len(), nx * ny);
    assert_eq!(truth.len(), nx * ny);
    let l = data_range.unwrap_or_else(|| {
        let hi = truth.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lo = truth.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        (hi - lo).max(1e-12)
    });
    let c1 = (0.01 * l) * (0.01 * l);
    let c2 = (0.03 * l) * (0.03 * l);

    // separable gaussian window
    const HALF: i64 = 5;
    let sigma = 1.5f64;
    let mut w = [0.0f64; 11];
    let mut norm = 0.0;
    for (i, wi) in w.iter_mut().enumerate() {
        let d = i as f64 - HALF as f64;
        *wi = (-d * d / (2.0 * sigma * sigma)).exp();
        norm += *wi;
    }
    for wi in w.iter_mut() {
        *wi /= norm;
    }

    // horizontal then vertical blur of the five moment maps
    let blur = |src: &[f64]| -> Vec<f64> {
        let mut tmp = vec![0.0f64; nx * ny];
        for y in 0..ny {
            for x in 0..nx {
                let mut acc = 0.0;
                for (i, &wi) in w.iter().enumerate() {
                    let xx = (x as i64 + i as i64 - HALF).clamp(0, nx as i64 - 1) as usize;
                    acc += wi * src[y * nx + xx];
                }
                tmp[y * nx + x] = acc;
            }
        }
        let mut out = vec![0.0f64; nx * ny];
        for y in 0..ny {
            for x in 0..nx {
                let mut acc = 0.0;
                for (i, &wi) in w.iter().enumerate() {
                    let yy = (y as i64 + i as i64 - HALF).clamp(0, ny as i64 - 1) as usize;
                    acc += wi * tmp[yy * nx + x];
                }
                out[y * nx + x] = acc;
            }
        }
        out
    };

    let xf: Vec<f64> = img.iter().map(|&v| v as f64).collect();
    let yf: Vec<f64> = truth.iter().map(|&v| v as f64).collect();
    let xx: Vec<f64> = xf.iter().map(|v| v * v).collect();
    let yy: Vec<f64> = yf.iter().map(|v| v * v).collect();
    let xy: Vec<f64> = xf.iter().zip(yf.iter()).map(|(a, b)| a * b).collect();

    let mx = blur(&xf);
    let my = blur(&yf);
    let mxx = blur(&xx);
    let myy = blur(&yy);
    let mxy = blur(&xy);

    let mut acc = 0.0;
    for i in 0..nx * ny {
        let vx = (mxx[i] - mx[i] * mx[i]).max(0.0);
        let vy = (myy[i] - my[i] * my[i]).max(0.0);
        let cxy = mxy[i] - mx[i] * my[i];
        let s = ((2.0 * mx[i] * my[i] + c1) * (2.0 * cxy + c2))
            / ((mx[i] * mx[i] + my[i] * my[i] + c1) * (vx + vy + c2));
        acc += s;
    }
    acc / (nx * ny) as f64
}

/// SSIM of the central slice of two volumes (the 2-D experiments use
/// `nz = 1`, where this is just SSIM of the image).
pub fn ssim_vol(a: &Vol3, b: &Vol3, data_range: Option<f64>) -> f64 {
    assert_eq!((a.nx, a.ny, a.nz), (b.nx, b.ny, b.nz));
    let k = a.nz / 2;
    ssim2d(a.slice(k), b.slice(k), a.nx, a.ny, data_range)
}

/// Memory footprint model used for Table 1: "enough to hold one copy of
/// the projection data and volume data stored as 32-bit floats".
pub fn one_copy_bytes(num_voxels: usize, num_proj_samples: usize) -> usize {
    4 * (num_voxels + num_proj_samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rmse_zero_for_identical() {
        let a = vec![1.0f32; 100];
        assert_eq!(rmse(&a, &a), 0.0);
        assert_eq!(mae(&a, &a), 0.0);
    }

    #[test]
    fn psnr_of_known_noise() {
        // constant error e against peak 1.0 → PSNR = -20 log10(e)
        let truth = vec![1.0f32; 1000];
        let img: Vec<f32> = truth.iter().map(|&v| v + 0.01).collect();
        let p = psnr(&img, &truth, Some(1.0));
        assert!((p - 40.0).abs() < 1e-4, "psnr {p}");
    }

    #[test]
    fn psnr_infinite_for_identical() {
        let a = vec![0.5f32; 10];
        assert!(psnr(&a, &a, Some(1.0)).is_infinite());
    }

    #[test]
    fn ssim_identical_is_one() {
        let mut rng = Rng::new(5);
        let mut img = vec![0.0f32; 32 * 32];
        rng.fill_uniform(&mut img, 0.0, 1.0);
        let s = ssim2d(&img, &img, 32, 32, Some(1.0));
        assert!((s - 1.0).abs() < 1e-9, "ssim {s}");
    }

    #[test]
    fn ssim_decreases_with_noise() {
        let mut rng = Rng::new(6);
        let nx = 48;
        // smooth structured image
        let truth: Vec<f32> = (0..nx * nx)
            .map(|i| {
                let x = (i % nx) as f32 / nx as f32;
                let y = (i / nx) as f32 / nx as f32;
                ((6.28 * x).sin() * (6.28 * y).cos() + 1.0) / 2.0
            })
            .collect();
        let small: Vec<f32> = truth.iter().map(|&v| v + 0.02 * rng.normal() as f32).collect();
        let large: Vec<f32> = truth.iter().map(|&v| v + 0.2 * rng.normal() as f32).collect();
        let s_small = ssim2d(&small, &truth, nx, nx, Some(1.0));
        let s_large = ssim2d(&large, &truth, nx, nx, Some(1.0));
        assert!(s_small > s_large, "{s_small} vs {s_large}");
        assert!(s_small > 0.8 && s_large < 0.8);
    }

    #[test]
    fn one_copy_model() {
        // Table 1 example: 512³ volume + 720×512² projections @ f32
        let v = 512usize * 512 * 512;
        let p = 720usize * 512 * 512;
        let gb = one_copy_bytes(v, p) as f64 / (1u64 << 30) as f64;
        assert!((gb - 1.203125).abs() < 1e-6, "gb {gb}");
    }
}
