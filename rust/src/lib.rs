//! # LEAP-RS — differentiable forward/back projectors for X-ray CT
//!
//! A Rust + JAX + Pallas reproduction of *"Differentiable Forward Projector
//! for X-ray Computed Tomography"* (Kim & Champley, Differentiable Almost
//! Everything Workshop @ ICML 2023) — the LLNL **LEAP** library.
//!
//! The crate provides:
//!
//! * [`geometry`] — quantitative CT geometry descriptions (mm units) for
//!   parallel-beam, fan-beam, axial cone-beam (flat and curved detector) and
//!   modular-beam (arbitrary source/detector poses per view).
//! * [`projector`] — on-the-fly forward (`A`) and **matched** back (`Aᵀ`)
//!   projectors using the Siddon, Joseph and Separable-Footprint (SF)
//!   models. No system matrix is ever materialized; the memory footprint is
//!   one copy of the volume plus one copy of the projections, exactly the
//!   paper's claim. Per-view geometry invariants (trig, detector bases,
//!   SF footprint bounds, Joseph marching axes) live in a reusable
//!   [`projector::ProjectionPlan`]: iterative solvers plan once per solve
//!   and the serving layer caches plans per scan config, while the direct
//!   path plans per view on the fly through the *same* execute code — the
//!   two paths are bit-identical.
//! * [`api`] — the **typed front door**: [`api::ScanBuilder`] validates
//!   a scan description (typed [`api::LeapError`]s, never panics) into a
//!   planned [`api::Scan`] with fallible `forward`/`back`/`solve`/
//!   `loss_grad`; the layers below are the panicking kernel layer that
//!   `Scan` dispatches to after validation.
//! * [`precision`] — reduced-precision **storage tiers**
//!   ([`precision::StorageTier`]: f32 / f16 / bf16, software-converted,
//!   no new deps): data at rest — cached plan coefficient tables and
//!   backprojection input sinograms — is held at the tier while every
//!   accumulation stays f32, keeping results bit-identical across
//!   thread counts within a tier. Selected per scan via
//!   [`api::ScanBuilder::storage_tier`] or process-wide via
//!   `LEAP_STORAGE`; see `docs/MEMORY.md`.
//! * [`vol`] — out-of-core volumes: [`vol::TiledVol3`] keeps
//!   slab-granular tiles on a file-backed store under a configurable
//!   residency budget and schedules the projector's range executors
//!   tile by tile — bit-identical to resident execution.
//! * [`backend`] — pluggable compute backends for the projection
//!   kernels: the scalar reference tier, the SIMD throughput tier
//!   (staged, lane-unrolled accumulation over the same coefficient
//!   enumerators — see `docs/BACKENDS.md`), and the capability-gated
//!   PJRT slot. Selected per scan via [`api::ScanBuilder::backend`],
//!   process-wide via `LEAP_BACKEND`, or by runtime detection; served
//!   sessions report their backend over the wire.
//! * [`ops`] — the differentiable operator layer: [`ops::LinearOp`]
//!   exposes `A`/`Aᵀ` as composable, batched, gradient-ready objects
//!   (scale, compose, mask views, form `AᵀA`), implemented by the
//!   planned projector, the stored system matrix and the FBP ramp
//!   filter; [`ops::ProjectionLoss`] returns data-fit losses with exact
//!   gradients through the matched adjoint. Every iterative solver is
//!   generic over `&dyn LinearOp`.
//! * [`tape`] — reverse-mode autodiff over operator pipelines:
//!   compose projectors/filters/solver iterations into a
//!   [`tape::Pipeline`] with trainable parameters (learnable step sizes,
//!   filter spectra, per-sample weights, convolution kernels), get exact
//!   loss gradients through the matched adjoints, train with
//!   deterministic [`tape::optim`] (SGD/Adam, mini-batch
//!   [`tape::optim::Fitter`] with bit-exact checkpointing) — unrolled
//!   GD, learned FBP and the unrolled-CNN (ItNet-style) solver ship as
//!   [`tape::unroll`] builders, servable over protocol v2
//!   ([`coordinator::Op::SessionPipelineGrad`]).
//! * [`nn`] — the neural kernel layer beneath the tape's conv nodes:
//!   direct (im2col-free) stride-1 same-padding Conv2d/Conv3d with
//!   exact input/weight/bias VJPs, average pooling and
//!   nearest-neighbour upsampling (exact adjoints of each other), and
//!   deterministic He-uniform initialization. Image tensors reuse the
//!   volume layout (`[w, h, c]`, channels on the slab axis), so a
//!   single-slice volume is a 1-channel image with no reshape.
//! * [`sysmatrix`] — the precomputed sparse system-matrix baseline the paper
//!   argues against (Lahiri et al. 2023 style), used by the Table-1 bench.
//! * [`recon`] — analytic (FBP/FDK) and iterative (SIRT, OS-SART, CGLS,
//!   MLEM, FISTA-TV) reconstruction built on the matched pairs, plus the
//!   sinogram-completion / data-consistency refinement pipeline of the
//!   paper's §3–4.
//! * [`phantom`] — Shepp-Logan (2-D/3-D), randomized "luggage" phantoms
//!   (ALERT dataset stand-in) and *analytic* ellipse sinograms for
//!   discretization-free accuracy studies.
//! * [`metrics`] — PSNR / SSIM / RMSE, matching the paper's evaluation.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs on the request path.
//!   Gated behind the **`pjrt`** cargo feature (off by default): without
//!   it a clear-error stub with the same API keeps every native path
//!   building and testing without the vendored XLA closure.
//! * [`coordinator`] — the serving layer: typed-[`coordinator::Op`]
//!   request router, dynamic batcher, worker pool, memory-budget
//!   admission control, protocol-v2 sessions and the dual-protocol TCP
//!   server (binary frames + legacy JSON; see `docs/PROTOCOL.md`).
//! * [`cluster`] — the multi-process sharded execution plane: `leap
//!   worker` processes dial the coordinator's shard channel
//!   ([`cluster::ShardServer`]) and [`cluster::ShardedOp`] scatters one
//!   operator application across them (forward: scatter views, concat;
//!   back: scatter output units, deterministic tree-reduce of partial
//!   volumes) with heartbeats, per-shard deadlines and bounded
//!   re-scatter — bit-identical to in-process execution at every
//!   worker count, including 0 (see `docs/CLUSTER.md`).
//! * [`util`] — self-contained substrates built for this repo: JSON,
//!   deterministic PRNG, scoped thread-pool parallel-for, a bench harness
//!   and a tiny CLI parser (no external deps beyond `xla`/`anyhow`).
//!
//! ## Quantitative conventions (identical to LEAP)
//!
//! * Detector pixel pitches and voxel sizes are specified in **mm**; the
//!   reconstructed volume is in **mm⁻¹**; projections are line integrals in
//!   dimensionless units. Halving the voxel size does not change projected
//!   values — verified by scaling tests.
//! * Voxel `(i, j, k)` has world-space center
//!   `x = (i − (nx−1)/2) · vx + cx` (same for y/z), with `c` the volume
//!   center offset in mm.
//! * Sinograms are stored `[view][row][col]`, volumes `[z][y][x]`,
//!   contiguous `f32` — the same layout the paper uses so buffers can be
//!   handed to the PJRT runtime without copies.
//!
//! ## Building and testing
//!
//! ```bash
//! cargo build --release && cargo test -q
//! ```
//!
//! No external dependencies beyond `anyhow` (and, only with
//! `--features pjrt`, the vendored `xla` crate).

// The numeric kernels index flat buffers by explicit arithmetic on
// purpose (the index math *is* the algorithm — Siddon/Joseph/SF walk
// strided layouts); suppress the style lints that object to that idiom.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::new_without_default)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_range_contains)]

pub mod util;
pub mod geometry;
pub mod array;
pub mod precision;
pub mod api;
pub mod backend;
pub mod projector;
pub mod vol;
pub mod ops;
pub mod nn;
pub mod tape;
pub mod sysmatrix;
pub mod recon;
pub mod phantom;
pub mod metrics;
pub mod io;
pub mod runtime;
pub mod coordinator;
pub mod cluster;
pub mod bench_harness;

pub use api::{LeapError, Scan, ScanBuilder, Solver};
pub use array::{Sino, Vol3};
pub use geometry::{ConeBeam, FanBeam, Geometry, ModularBeam, ParallelBeam, VolumeGeometry};
pub use precision::StorageTier;
