//! The coordinator side of the shard channel: a nonblocking,
//! readiness-polled endpoint worker processes dial into.
//!
//! [`ShardServer`] binds its own port (separate from the client-facing
//! serving port) and runs one event-loop thread over
//! [`crate::util::netpoll`]: the listener, a [`Waker`] wakeup fd, and
//! every connected worker socket sit in one poll set, each worker a
//! nonblocking state machine with incremental protocol-v2 frame
//! reassembly — the same discipline as the client-facing server in
//! [`crate::coordinator::server`].
//!
//! ## Protocol (v2 frames, append-only meta keys)
//!
//! * Worker → coordinator `Hello` with meta `{"role": "worker"}`;
//!   coordinator replies `Hello` with `{"worker_id": n}`.
//! * Heartbeats are `Hello` frames with `{"role": "worker", "hb": 1}`,
//!   sent by a dedicated worker-side timer thread every heartbeat
//!   period — idle or mid-compute alike. A worker silent past
//!   [`ShardServerOptions::heartbeat_timeout`] is dropped and its
//!   in-flight shard re-scattered; as a belt-and-braces guard against
//!   single-threaded workers (heartbeat silence while computing), a
//!   worker with a shard in flight is exempt from the silence check —
//!   the per-shard deadline already bounds how long a busy worker can
//!   hold a shard.
//! * Shard tasks are `Request` frames whose meta carries the full scan
//!   config (the OpenSession meta keys) **plus** `"shard"` ("fp"|"bp")
//!   and the unit range `"u0"`/`"u1"` — see `docs/PROTOCOL.md`. Because
//!   every task is self-describing, a restarted worker re-establishes
//!   the session's pinned plan from the next task frame alone: there is
//!   no coordinator-side session state to resynchronize.
//! * Replies are `Response` (payload = the shard result) or `Error`
//!   frames; errors surface to the submitter as typed
//!   [`LeapError::Remote`].
//!
//! ## Failure handling
//!
//! One shard is in flight per worker at a time. A shard that misses its
//! deadline, or whose worker disconnects or goes heartbeat-silent, is
//! requeued with a **fresh frame id** (so a late reply to the old id is
//! recognized as stale and dropped) and re-scattered to an idle worker
//! — preferring one **other than the worker it just failed on** (that
//! one may still be serially chewing the stale shard) — up to
//! [`ShardServerOptions::max_retries`] times, after which the submitter
//! gets the error and decides (the operator layer falls back to
//! in-process execution, so requests still complete). If the last
//! registered worker disappears, every queued shard is failed
//! immediately with [`LeapError::Remote`] rather than left waiting for
//! a worker that may never come: submitters must never block forever,
//! and the operator layer's fallback keeps the request completing
//! in-process. Every retry is counted in the server's own [`Telemetry`]
//! and served as the `cluster` rows of `__stats`.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::LeapError;
use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::wire::{decode_frame_bytes, encode_frame_parts, Frame, FrameKind};
use crate::util::json::Json;
use crate::util::netpoll::{poll_fds, raw_fd, PollFd, Waker, POLLIN, POLLOUT};

/// Default silence window after which a worker is presumed dead.
pub const HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(10);
/// Default per-shard completion deadline before a re-scatter.
pub const TASK_DEADLINE: Duration = Duration::from_secs(60);
/// Default bound on re-scatters per shard (beyond the first dispatch).
pub const MAX_RETRIES: u32 = 2;

/// Tuning knobs for [`ShardServer::start_with`]. Tests shrink the
/// timeouts to exercise the failure paths in milliseconds.
#[derive(Clone, Debug)]
pub struct ShardServerOptions {
    /// Drop a worker silent (no frames, no heartbeats) this long.
    pub heartbeat_timeout: Duration,
    /// Re-scatter a shard not answered within this deadline.
    pub task_deadline: Duration,
    /// Give up on a shard after this many re-scatters and surface the
    /// error to the submitter.
    pub max_retries: u32,
}

impl Default for ShardServerOptions {
    fn default() -> ShardServerOptions {
        ShardServerOptions {
            heartbeat_timeout: HEARTBEAT_TIMEOUT,
            task_deadline: TASK_DEADLINE,
            max_retries: MAX_RETRIES,
        }
    }
}

/// One queued or in-flight shard.
struct Task {
    /// Telemetry row ("shard_fp" / "shard_bp").
    label: &'static str,
    meta: Json,
    payload: Arc<Vec<f32>>,
    /// Element count the reply payload must have.
    expected_len: usize,
    retries: u32,
    submitted: Instant,
    /// Worker id of the last failed dispatch — a retry prefers any
    /// other idle worker (the failed one may still be serially
    /// computing the stale shard even though its slot looks free).
    last_worker: Option<u64>,
    reply: mpsc::Sender<Result<Vec<f32>, LeapError>>,
}

/// Handle to one submitted shard; [`PendingShard::wait`] blocks for the
/// result. Dropping it abandons the shard (the reply send is ignored).
pub struct PendingShard {
    rx: mpsc::Receiver<Result<Vec<f32>, LeapError>>,
}

impl PendingShard {
    /// Block until the shard completes, fails permanently, or the
    /// server stops.
    pub fn wait(self) -> Result<Vec<f32>, LeapError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(LeapError::Io("shard server stopped".into())))
    }
}

/// State shared between submitters and the event-loop thread.
struct Shared {
    queue: Mutex<VecDeque<Task>>,
    waker: Waker,
    connected: AtomicUsize,
    telemetry: Telemetry,
    stop: AtomicBool,
    opts: ShardServerOptions,
}

/// The coordinator-side shard channel; see the module docs. Dropping
/// stops the event loop: queued shards error out, workers see EOF and
/// exit cleanly.
pub struct ShardServer {
    /// The bound shard-channel address workers dial
    /// (`leap worker --connect <addr>`).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    loop_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ShardServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") with default options.
    pub fn start(addr: &str) -> Result<ShardServer, LeapError> {
        ShardServer::start_with(addr, ShardServerOptions::default())
    }

    /// Bind `addr` and run the shard channel on one event-loop thread.
    pub fn start_with(addr: &str, opts: ShardServerOptions) -> Result<ShardServer, LeapError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            waker: Waker::new()?,
            connected: AtomicUsize::new(0),
            telemetry: Telemetry::new(),
            stop: AtomicBool::new(false),
            opts,
        });
        let shared2 = shared.clone();
        let handle = std::thread::Builder::new()
            .name("leap-shard-chan".into())
            .spawn(move || event_loop(listener, shared2))
            .map_err(|e| LeapError::Io(e.to_string()))?;
        Ok(ShardServer { addr: local, shared, loop_handle: Mutex::new(Some(handle)) })
    }

    /// Number of currently connected (registered) workers. The operator
    /// layer treats 0 as "run in-process".
    pub fn workers(&self) -> usize {
        self.shared.connected.load(Ordering::Relaxed)
    }

    /// The shard channel's own telemetry: `shard_fp`/`shard_bp` rows
    /// with dispatch counts, latency aggregates and per-shard retry
    /// counts (served as the `cluster` rows of `__stats`).
    pub fn telemetry(&self) -> &Telemetry {
        &self.shared.telemetry
    }

    /// Queue one shard for dispatch to an idle worker. `meta` must be
    /// the self-describing task meta (scan config + `"shard"`/`"u0"`/
    /// `"u1"`), `expected_len` the element count the reply must have.
    pub fn submit(
        &self,
        label: &'static str,
        meta: Json,
        payload: Arc<Vec<f32>>,
        expected_len: usize,
    ) -> PendingShard {
        let (tx, rx) = mpsc::channel();
        self.shared.queue.lock().unwrap().push_back(Task {
            label,
            meta,
            payload,
            expected_len,
            retries: 0,
            submitted: Instant::now(),
            last_worker: None,
            reply: tx,
        });
        self.shared.waker.wake();
        PendingShard { rx }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
        if let Some(h) = self.loop_handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// One connected worker: a nonblocking socket with incremental frame
/// reassembly and a pending-write buffer, plus at most one in-flight
/// shard.
struct WorkerConn {
    sock: TcpStream,
    id: u64,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    last_seen: Instant,
    /// Hello exchanged — only registered workers receive shards.
    registered: bool,
    /// `(frame id, task, deadline)` of the dispatched shard, if any.
    inflight: Option<(u64, Task, Instant)>,
    failed: bool,
}

fn elapsed_us(t: Instant) -> u64 {
    t.elapsed().as_micros() as u64
}

/// Requeue `task` with a fresh dispatch slot, or surface `err` to the
/// submitter once the retry budget is spent. `from_worker` is the
/// worker the dispatch just failed on — the retry will prefer a
/// different idle worker.
fn retry_or_fail(shared: &Shared, mut task: Task, from_worker: u64, err: LeapError) {
    task.last_worker = Some(from_worker);
    if task.retries < shared.opts.max_retries {
        task.retries += 1;
        shared.telemetry.record_retry(task.label);
        shared.queue.lock().unwrap().push_front(task);
    } else {
        shared.telemetry.record(task.label, elapsed_us(task.submitted), 0, false);
        let _ = task.reply.send(Err(err));
    }
}

/// Flush as much of the worker's pending write buffer as the socket
/// accepts right now.
fn flush(w: &mut WorkerConn) {
    while w.woff < w.wbuf.len() {
        match w.sock.write(&w.wbuf[w.woff..]) {
            Ok(0) => {
                w.failed = true;
                return;
            }
            Ok(n) => w.woff += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                w.failed = true;
                return;
            }
        }
    }
    if w.woff == w.wbuf.len() {
        w.wbuf.clear();
        w.woff = 0;
    } else if w.woff > (1 << 20) {
        w.wbuf.drain(..w.woff);
        w.woff = 0;
    }
}

/// Read everything currently available and decode complete frames.
fn read_frames(w: &mut WorkerConn) -> Vec<Frame> {
    let mut frames = Vec::new();
    let mut chunk = [0u8; 64 * 1024];
    loop {
        match w.sock.read(&mut chunk) {
            Ok(0) => {
                w.failed = true;
                break;
            }
            Ok(n) => {
                w.rbuf.extend_from_slice(&chunk[..n]);
                w.last_seen = Instant::now();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                w.failed = true;
                break;
            }
        }
    }
    loop {
        match decode_frame_bytes(&w.rbuf) {
            Ok(Some((frame, consumed))) => {
                w.rbuf.drain(..consumed);
                frames.push(frame);
            }
            Ok(None) => break,
            Err(_) => {
                w.failed = true;
                break;
            }
        }
    }
    frames
}

/// Handle one decoded frame from `w`.
fn handle_frame(shared: &Shared, w: &mut WorkerConn, frame: Frame) {
    match frame.kind {
        FrameKind::Hello => {
            // first Hello registers; later ones are heartbeats (the
            // read itself already refreshed last_seen)
            if !w.registered {
                if frame.meta.get_str("role") != Some("worker") {
                    w.failed = true;
                    return;
                }
                w.registered = true;
                shared.connected.fetch_add(1, Ordering::Relaxed);
                let meta = Json::obj(vec![("worker_id", Json::Num(w.id as f64))]);
                match encode_frame_parts(FrameKind::Hello, w.id, &meta, &[]) {
                    Ok(bytes) => w.wbuf.extend_from_slice(&bytes),
                    Err(_) => w.failed = true,
                }
            }
        }
        FrameKind::Response => {
            let matches = w.inflight.as_ref().is_some_and(|(id, _, _)| *id == frame.id);
            if !matches {
                return; // stale reply to a re-scattered shard: drop
            }
            let (_, task, _) = w.inflight.take().expect("matched above");
            if frame.payload.len() == task.expected_len {
                let us = elapsed_us(task.submitted);
                shared.telemetry.record(task.label, us, us, true);
                let _ = task.reply.send(Ok(frame.payload));
            } else {
                let err = LeapError::Remote {
                    code: crate::api::codes::SHAPE_MISMATCH,
                    message: format!(
                        "worker {} shard reply has {} elements, expected {}",
                        w.id,
                        frame.payload.len(),
                        task.expected_len
                    ),
                };
                retry_or_fail(shared, task, w.id, err);
            }
        }
        FrameKind::Error => {
            let matches = w.inflight.as_ref().is_some_and(|(id, _, _)| *id == frame.id);
            if !matches {
                return; // stale error for a re-scattered shard: drop
            }
            let (_, task, _) = w.inflight.take().expect("matched above");
            let e = frame.to_error();
            let remote =
                LeapError::Remote { code: e.code(), message: format!("worker {}: {e}", w.id) };
            retry_or_fail(shared, task, w.id, remote);
        }
        // anything else on the shard channel is a protocol violation
        _ => w.failed = true,
    }
}

fn event_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut workers: Vec<WorkerConn> = Vec::new();
    let mut next_worker_id: u64 = 1;
    let mut next_task_id: u64 = 1;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        // poll set: listener, waker, then one slot per worker (POLLOUT
        // only while a write is actually pending)
        let nw = workers.len();
        let mut fds = Vec::with_capacity(2 + nw);
        fds.push(PollFd::new(raw_fd(&listener), POLLIN));
        fds.push(PollFd::new(shared.waker.fd(), POLLIN));
        for w in &workers {
            let mut ev = POLLIN;
            if w.woff < w.wbuf.len() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(raw_fd(&w.sock), ev));
        }
        // timeout: the nearest shard deadline, bounded by a heartbeat
        // sweep tick
        let now = Instant::now();
        let mut timeout = Duration::from_millis(500);
        for w in &workers {
            if let Some((_, _, deadline)) = &w.inflight {
                timeout = timeout.min(deadline.saturating_duration_since(now));
            }
        }
        poll_fds(&mut fds, timeout.max(Duration::from_millis(1)));
        if fds[1].readable() {
            shared.waker.drain();
        }
        // worker I/O (only the workers the poll set covered)
        for (i, w) in workers.iter_mut().take(nw).enumerate() {
            let pf = &fds[2 + i];
            if pf.hangup() && !pf.readable() {
                w.failed = true;
                continue;
            }
            if pf.readable() {
                for frame in read_frames(w) {
                    handle_frame(&shared, w, frame);
                }
            }
            if pf.writable() {
                flush(w);
            }
        }
        // new workers
        if fds[0].readable() {
            loop {
                match listener.accept() {
                    Ok((sock, _)) => {
                        let _ = sock.set_nonblocking(true);
                        let _ = sock.set_nodelay(true);
                        workers.push(WorkerConn {
                            sock,
                            id: next_worker_id,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            woff: 0,
                            last_seen: Instant::now(),
                            registered: false,
                            inflight: None,
                            failed: false,
                        });
                        next_worker_id += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
        // deadline sweep: a shard past its deadline is re-scattered
        // with a fresh id; the worker stays connected (its eventual
        // reply is recognized as stale) but heartbeat silence drops it
        let now = Instant::now();
        for w in workers.iter_mut() {
            let expired = w.inflight.as_ref().is_some_and(|(_, _, d)| now >= *d);
            if expired {
                let (_, task, _) = w.inflight.take().expect("expired above");
                retry_or_fail(
                    &shared,
                    task,
                    w.id,
                    LeapError::Remote {
                        code: crate::api::codes::IO,
                        message: format!("worker {} missed the shard deadline", w.id),
                    },
                );
            }
            // heartbeat silence drops a worker — but never one with a
            // shard in flight: a single-threaded worker sends nothing
            // while computing, and the per-shard deadline above already
            // bounds how long a busy (or dead-while-busy) worker can
            // hold its shard
            if w.registered
                && w.inflight.is_none()
                && now.duration_since(w.last_seen) > shared.opts.heartbeat_timeout
            {
                w.failed = true;
            }
        }
        // drop failed workers, re-scattering whatever they held
        workers.retain_mut(|w| {
            if !w.failed {
                return true;
            }
            if w.registered {
                shared.connected.fetch_sub(1, Ordering::Relaxed);
            }
            if let Some((_, task, _)) = w.inflight.take() {
                retry_or_fail(
                    &shared,
                    task,
                    w.id,
                    LeapError::Remote {
                        code: crate::api::codes::IO,
                        message: format!("worker {} connection lost", w.id),
                    },
                );
            }
            false
        });
        // with no registered workers left, queued shards can never be
        // dispatched and their retry budget never advances — fail them
        // now with a typed Remote error so submitters take the
        // in-process fallback instead of blocking forever (anything
        // submitted after a worker registers queues normally)
        if !workers.iter().any(|w| w.registered) {
            let drained: Vec<Task> = shared.queue.lock().unwrap().drain(..).collect();
            for task in drained {
                shared.telemetry.record(task.label, elapsed_us(task.submitted), 0, false);
                let _ = task.reply.send(Err(LeapError::Remote {
                    code: crate::api::codes::IO,
                    message: "no workers connected to the shard channel".into(),
                }));
            }
        }
        // dispatch queued shards to idle registered workers; a retried
        // shard prefers a worker other than the one it just failed on
        // (that one may still be serially computing the stale shard
        // even though its in-flight slot was cleared)
        {
            let mut queue = shared.queue.lock().unwrap();
            let mut idle: Vec<usize> = (0..workers.len())
                .filter(|&i| {
                    workers[i].registered && workers[i].inflight.is_none() && !workers[i].failed
                })
                .collect();
            while !idle.is_empty() {
                let Some(task) = queue.pop_front() else { break };
                let pick = idle
                    .iter()
                    .position(|&i| task.last_worker != Some(workers[i].id))
                    .unwrap_or(0);
                let w = &mut workers[idle.swap_remove(pick)];
                let id = next_task_id;
                next_task_id += 1;
                match encode_frame_parts(FrameKind::Request, id, &task.meta, &task.payload) {
                    Ok(bytes) => {
                        w.wbuf.extend_from_slice(&bytes);
                        w.inflight = Some((id, task, Instant::now() + shared.opts.task_deadline));
                    }
                    Err(e) => {
                        let _ = task.reply.send(Err(e));
                    }
                }
            }
        }
        // opportunistic flush so small dispatches don't wait a poll tick
        for w in workers.iter_mut() {
            if w.woff < w.wbuf.len() {
                flush(w);
            }
        }
    }
    // shutting down: error out everything still queued or in flight
    for task in shared.queue.lock().unwrap().drain(..) {
        let _ = task.reply.send(Err(LeapError::Io("shard server stopped".into())));
    }
    for mut w in workers {
        if let Some((_, task, _)) = w.inflight.take() {
            let _ = task.reply.send(Err(LeapError::Io("shard server stopped".into())));
        }
    }
}
