//! `leap::cluster` — the multi-process sharded execution plane.
//!
//! One operator application spreads across worker **processes**: the
//! coordinator runs a [`ShardServer`] (a second listening port — the
//! shard channel) that `leap worker` processes dial into, and
//! [`ShardedOp`] scatters each forward/back application across them:
//!
//! * **Forward** scatters contiguous *view* ranges; each worker returns
//!   its view slab and the coordinator concatenates them in plan order.
//! * **Back** scatters contiguous *output-unit* ranges (the same units
//!   as [`crate::ops::ViewSharded`]: z·y rows, y rows or z slabs,
//!   depending on the plan kind); each worker returns a full-size
//!   partial volume that is zero outside its owned units, and the
//!   coordinator combines them with [`reduce::tree_reduce`] in a
//!   **fixed, shard-count-independent order**.
//!
//! ## Determinism contract
//!
//! The shard plan ([`ShardPlanner`]) depends **only on the unit count**
//! — never on how many workers are alive — so the same scan always
//! splits the same way, every shard is executed by the same
//! bit-identical range kernels as in-process execution
//! (`forward_range_into_with_threads` / `back_range_into_with_threads`,
//! property-tested over arbitrary partitions in
//! `tests/range_property.rs`), and the reduction order is a pure
//! function of the shard count. Results are therefore bit-identical to
//! in-process execution at every worker count — 0 (pure in-process
//! fallback), 1, 2, 4, … — and across worker deaths mid-request (a
//! retried shard lands in its original plan slot).
//!
//! ## Failure handling
//!
//! Shards that time out or lose their worker are re-scattered to
//! survivors with a bounded retry budget (see [`transport`]); a shard
//! that exhausts it falls back to in-process execution of that range,
//! so a request completes even if every worker dies mid-solve. Worker
//! errors surface as typed [`LeapError::Remote`]. Per-shard dispatch /
//! retry / latency telemetry rides the `cluster` rows of `__stats`.
//!
//! See `docs/CLUSTER.md` for topology and operations.

pub mod reduce;
pub mod transport;
pub mod worker;

pub use transport::{PendingShard, ShardServer, ShardServerOptions};
pub use worker::{run_worker, run_worker_with, WorkerOptions};

use std::sync::Arc;

use crate::api::LeapError;
use crate::array::{Sino, Vol3};
use crate::geometry::config::{geometry_to_json, volume_to_json};
use crate::ops::{LinearOp, Shape};
use crate::projector::ProjectionPlan;
use crate::util::json::Json;
use crate::util::pool;

/// Splits a unit range into the shard plan. The split is a pure
/// function of the unit count — worker count never enters — which is
/// what keeps sharded results bit-identical at every cluster size.
pub struct ShardPlanner;

impl ShardPlanner {
    /// Target shard count: enough slack for a handful of workers to
    /// load-balance, small enough that per-shard payload overhead
    /// (forward ships the whole volume per shard) stays bounded.
    pub const TARGET_SHARDS: usize = 8;

    /// The shard ranges for `units` output units: contiguous, in order,
    /// sizes differing by at most one (`pool::chunk_ranges`), at most
    /// [`Self::TARGET_SHARDS`] of them.
    pub fn shard_ranges(units: usize) -> Vec<(usize, usize)> {
        pool::chunk_ranges(units, Self::TARGET_SHARDS.min(units.max(1)))
    }
}

/// A [`LinearOp`] that scatters each application across the shard
/// channel's workers — the multi-process sibling of
/// [`crate::ops::ViewSharded`]. With no workers connected it executes
/// in-process through the identical range kernels, so it is always
/// safe to route through.
pub struct ShardedOp {
    plan: Arc<ProjectionPlan>,
    server: Arc<ShardServer>,
    /// Scan-identity meta every task frame carries (the OpenSession
    /// keys), cloned and extended with `"shard"`/`"u0"`/`"u1"` per task.
    base_meta: Json,
}

impl ShardedOp {
    pub fn new(plan: Arc<ProjectionPlan>, server: Arc<ShardServer>) -> ShardedOp {
        let base_meta = Json::obj(vec![
            (
                "config",
                Json::obj(vec![
                    ("geometry", geometry_to_json(plan.geom())),
                    ("volume", volume_to_json(plan.vg())),
                ]),
            ),
            ("model", Json::Str(plan.model().name().into())),
            ("threads", Json::Num(plan.threads() as f64)),
            ("backend", Json::Str(plan.backend().name().into())),
            ("storage", Json::Str(plan.storage().name().into())),
        ]);
        ShardedOp { plan, server, base_meta }
    }

    /// The plan this operator shards.
    pub fn plan(&self) -> &Arc<ProjectionPlan> {
        &self.plan
    }

    fn task_meta(&self, kind: &str, u0: usize, u1: usize) -> Json {
        let mut meta = self.base_meta.clone();
        if let Json::Obj(m) = &mut meta {
            m.insert("shard".into(), Json::Str(kind.into()));
            m.insert("u0".into(), Json::Num(u0 as f64));
            m.insert("u1".into(), Json::Num(u1 as f64));
        }
        meta
    }

    /// `A·x` into a [`Sino`] (the session serving path's entry point).
    pub fn forward(&self, vol: &Vol3) -> Sino {
        let mut out = self.plan.new_sino();
        self.apply_into(&vol.data, &mut out.data);
        out
    }

    /// `Aᵀ·y` into a [`Vol3`].
    pub fn back(&self, sino: &Sino) -> Vol3 {
        let mut vol = self.plan.new_vol();
        self.adjoint_into(&sino.data, &mut vol.data);
        vol
    }
}

impl LinearOp for ShardedOp {
    fn domain_shape(&self) -> Shape {
        Shape::vol(self.plan.vg())
    }

    fn range_shape(&self) -> Shape {
        Shape::sino(self.plan.geom())
    }

    /// Forward: scatter view ranges, concatenate slabs in plan order.
    /// Shards whose retry budget runs out execute in-process — the
    /// result is bit-identical either way, so fallback is silent except
    /// in telemetry.
    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.domain_shape().numel(), "sharded forward input length");
        assert_eq!(y.len(), self.range_shape().numel(), "sharded forward output length");
        let units = self.plan.forward_shard_units();
        let ranges = ShardPlanner::shard_ranges(units);
        let per_view = self.plan.geom().nrows() * self.plan.geom().ncols();
        if self.server.workers() == 0 {
            // pure in-process fallback: same ranges, same kernels
            let vol = Vol3::from_vec(self.plan.vg().nx, self.plan.vg().ny, self.plan.vg().nz, x.to_vec());
            let mut sino = self.plan.new_sino();
            let threads = self.plan.threads().max(1);
            for &(u0, u1) in &ranges {
                self.plan.forward_range_into_with_threads(&vol, &mut sino, threads, u0, u1);
            }
            y.copy_from_slice(&sino.data);
            return;
        }
        let payload = Arc::new(x.to_vec());
        let pending: Vec<(usize, usize, PendingShard)> = ranges
            .iter()
            .map(|&(u0, u1)| {
                let meta = self.task_meta("fp", u0, u1);
                let expected = (u1 - u0) * per_view;
                (u0, u1, self.server.submit("shard_fp", meta, payload.clone(), expected))
            })
            .collect();
        let mut local: Option<(Vol3, Sino)> = None;
        for (u0, u1, shard) in pending {
            match shard.wait() {
                Ok(slab) => y[u0 * per_view..u1 * per_view].copy_from_slice(&slab),
                Err(_) => {
                    // retry budget exhausted (e.g. every worker died):
                    // execute this range in-process — bit-identical
                    let (vol, sino) = local.get_or_insert_with(|| {
                        let vg = self.plan.vg();
                        (
                            Vol3::from_vec(vg.nx, vg.ny, vg.nz, x.to_vec()),
                            self.plan.new_sino(),
                        )
                    });
                    let threads = self.plan.threads().max(1);
                    self.plan.forward_range_into_with_threads(vol, sino, threads, u0, u1);
                    y[u0 * per_view..u1 * per_view]
                        .copy_from_slice(&sino.data[u0 * per_view..u1 * per_view]);
                }
            }
        }
    }

    /// Back: scatter output-unit ranges, tree-reduce the full-size
    /// partial volumes in the fixed order (see [`reduce`]). Failed
    /// shards produce their partial in-process, landing in the same
    /// plan slot — the reduction order never changes.
    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        assert_eq!(y.len(), self.range_shape().numel(), "sharded back input length");
        assert_eq!(x.len(), self.domain_shape().numel(), "sharded back output length");
        let units = self.plan.back_shard_units();
        let ranges = ShardPlanner::shard_ranges(units);
        let threads = self.plan.threads().max(1);
        if self.server.workers() == 0 {
            let g = self.plan.geom();
            let sino = Sino::from_vec(g.nviews(), g.nrows(), g.ncols(), y.to_vec());
            let mut vol = self.plan.new_vol();
            for &(u0, u1) in &ranges {
                self.plan.back_range_into_with_threads(&sino, &mut vol, threads, u0, u1);
            }
            x.copy_from_slice(&vol.data);
            return;
        }
        let payload = Arc::new(y.to_vec());
        let numel = self.domain_shape().numel();
        let pending: Vec<(usize, usize, PendingShard)> = ranges
            .iter()
            .map(|&(u0, u1)| {
                let meta = self.task_meta("bp", u0, u1);
                (u0, u1, self.server.submit("shard_bp", meta, payload.clone(), numel))
            })
            .collect();
        let mut local_sino: Option<Sino> = None;
        // collect partials in shard-plan order — the reduction input
        // order, and therefore the reduction itself, is fixed
        let partials: Vec<Vec<f32>> = pending
            .into_iter()
            .map(|(u0, u1, shard)| match shard.wait() {
                Ok(partial) => partial,
                Err(_) => {
                    let sino = local_sino.get_or_insert_with(|| {
                        let g = self.plan.geom();
                        Sino::from_vec(g.nviews(), g.nrows(), g.ncols(), y.to_vec())
                    });
                    let mut vol = self.plan.new_vol();
                    self.plan.back_range_into_with_threads(sino, &mut vol, threads, u0, u1);
                    vol.data
                }
            })
            .collect();
        match reduce::tree_reduce(partials) {
            Some(reduced) => x.copy_from_slice(&reduced),
            None => x.fill(0.0),
        }
    }
}
