//! The worker side of the shard channel: `leap worker` runs this.
//!
//! A worker process dials the coordinator's shard channel
//! ([`super::ShardServer`]), registers with a `Hello` frame
//! (`{"role": "worker"}`), then serves shard tasks until the
//! coordinator closes the connection. The serve loop blocks on the
//! socket reading task frames; liveness is proven by a **dedicated
//! heartbeat thread** that sends a heartbeat `Hello` (`{"hb": 1}`)
//! every heartbeat period — idle or mid-compute alike, so a shard
//! whose compute runs past the coordinator's heartbeat timeout never
//! gets its (perfectly healthy) worker presumed dead. Replies and
//! heartbeats go through one mutex-guarded duplicate of the socket so
//! frames never interleave mid-frame.
//!
//! ## Tasks are self-describing — the shard/replica handshake
//!
//! Every task frame's meta is a superset of the protocol-v2
//! `OpenSession` meta: the full scan config plus `"shard"` ("fp"|"bp")
//! and the owned unit range `"u0"`/`"u1"`. The worker opens the scan in
//! its **local** [`SessionRegistry`] on first sight (keyed by the
//! canonical JSON of the config, so repeated tasks reuse the pinned
//! plan) and executes the range through the same
//! `forward_range_into_with_threads` / `back_range_into_with_threads`
//! kernels as in-process execution — which is what makes sharded
//! results bit-identical. Because the plan is re-derivable from any
//! task frame, a worker that crashes and restarts needs no session
//! resynchronization: it re-registers, receives a re-scattered task,
//! and rebuilds the plan from that frame alone.
//!
//! Forward tasks carry the whole volume and return the owned view slab
//! (`[u0, u1)` views, contiguous). Back tasks carry the whole sinogram
//! and return a **full-size** partial volume that is zero outside the
//! owned units (the coordinator tree-reduces those — see
//! [`super::reduce`]).

use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::api::LeapError;
use crate::array::{Sino, Vol3};
use crate::coordinator::wire::{read_frame, write_frame, write_frame_parts, Frame, FrameKind};
use crate::coordinator::SessionRegistry;
use crate::util::json::Json;

/// Default interval between worker heartbeats. Must be well under the
/// coordinator's [`super::transport::HEARTBEAT_TIMEOUT`].
pub const HEARTBEAT_PERIOD: Duration = Duration::from_secs(2);

/// Tuning knobs for [`run_worker_with`].
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// Heartbeat send interval. A dedicated timer thread sends on this
    /// cadence whether the worker is idle or mid-compute, so long
    /// shards never get the worker presumed dead.
    pub heartbeat_period: Duration,
    /// Override the execution thread count (`None` = the plan's own).
    /// Safe at any value: results are bit-identical across thread
    /// counts, so this is a per-host throughput knob only.
    pub threads: Option<usize>,
    /// Initial-connect attempts (100 ms apart) before giving up —
    /// workers are routinely launched a beat before the coordinator.
    pub connect_retries: u32,
}

impl Default for WorkerOptions {
    fn default() -> WorkerOptions {
        WorkerOptions { heartbeat_period: HEARTBEAT_PERIOD, threads: None, connect_retries: 50 }
    }
}

/// Serve shards from `connect` (host:port of the coordinator's shard
/// channel) until the coordinator closes the connection. Returns `Ok`
/// on a clean close.
pub fn run_worker(connect: &str) -> Result<(), LeapError> {
    run_worker_with(connect, WorkerOptions::default())
}

/// [`run_worker`] with explicit options.
pub fn run_worker_with(connect: &str, opts: WorkerOptions) -> Result<(), LeapError> {
    let mut sock = connect_with_retries(connect, opts.connect_retries)?;
    let _ = sock.set_nodelay(true);
    // register: Hello out, Hello (with our assigned id) back
    let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
    write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[])?;
    let reply = read_frame(&mut sock)?
        .ok_or_else(|| LeapError::Protocol("shard channel closed before hello reply".into()))?;
    if reply.kind != FrameKind::Hello {
        return Err(LeapError::Protocol(format!(
            "expected hello on the shard channel, got {:?}",
            reply.kind
        )));
    }
    // reads stay on `sock`; every write (reply or heartbeat) goes
    // through one mutex-guarded duplicate so frames never interleave
    let wsock = Arc::new(Mutex::new(
        sock.try_clone().map_err(|e| LeapError::Io(e.to_string()))?,
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let hb = spawn_heartbeat(wsock.clone(), stop.clone(), opts.heartbeat_period);
    let result = serve_loop(&mut sock, &wsock, opts.threads);
    stop.store(true, Ordering::SeqCst);
    let _ = hb.join();
    result
}

/// The heartbeat timer thread: proves liveness every `period` whether
/// the serve loop is idle or deep in a shard compute. Exits when `stop`
/// is set or the channel dies (the serve loop notices the same death on
/// its next read).
fn spawn_heartbeat(
    wsock: Arc<Mutex<TcpStream>>,
    stop: Arc<AtomicBool>,
    period: Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let heartbeat =
            Json::obj(vec![("role", Json::Str("worker".into())), ("hb", Json::Num(1.0))]);
        // sleep in short slices so a stop request is noticed promptly
        let slice = Duration::from_millis(25).min(period.max(Duration::from_millis(1)));
        let mut slept = Duration::ZERO;
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(slice);
            slept += slice;
            if slept < period {
                continue;
            }
            slept = Duration::ZERO;
            let Ok(mut s) = wsock.lock() else { return };
            if write_frame_parts(&mut *s, FrameKind::Hello, 0, &heartbeat, &[]).is_err()
                || s.flush().is_err()
            {
                return; // channel gone: nothing left to keep alive
            }
        }
    })
}

/// Serve task frames from `sock` until the coordinator closes the
/// channel; replies go through the shared write socket.
fn serve_loop(
    sock: &mut TcpStream,
    wsock: &Mutex<TcpStream>,
    threads_override: Option<usize>,
) -> Result<(), LeapError> {
    // local sessions: one pinned plan per distinct scan config seen in
    // task frames (the shard/replica handshake — see module docs)
    let registry = SessionRegistry::new();
    let mut plans: HashMap<String, u64> = HashMap::new();
    loop {
        let Some(frame) = read_frame(sock)? else {
            return Ok(()); // coordinator closed the channel: clean exit
        };
        match frame.kind {
            FrameKind::Request => {
                let served = serve_task(&registry, &mut plans, &frame, threads_override);
                let mut w = wsock.lock().map_err(|_| {
                    LeapError::Io("shard channel write half poisoned".into())
                })?;
                match served {
                    Ok(payload) => {
                        write_frame_parts(
                            &mut *w,
                            FrameKind::Response,
                            frame.id,
                            &Json::Null,
                            &payload,
                        )?;
                    }
                    Err(e) => write_frame(&mut *w, &Frame::error(frame.id, &e))?,
                }
                let _ = w.flush();
            }
            FrameKind::Hello => {} // coordinator-side ping: ignore
            other => {
                let e = LeapError::Protocol(format!("unexpected {other:?} on shard channel"));
                let mut w = wsock
                    .lock()
                    .map_err(|_| LeapError::Io("shard channel write half poisoned".into()))?;
                write_frame(&mut *w, &Frame::error(frame.id, &e))?;
                let _ = w.flush();
            }
        }
    }
}

fn connect_with_retries(connect: &str, retries: u32) -> Result<TcpStream, LeapError> {
    let mut last = None;
    for _ in 0..retries.max(1) {
        match TcpStream::connect(connect) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    Err(LeapError::Io(format!(
        "shard channel {connect} unreachable: {}",
        last.map(|e| e.to_string()).unwrap_or_default()
    )))
}

/// Execute one shard task frame. The plan cache key is the canonical
/// (sorted-key) JSON of the scan-identity meta, so every task with the
/// same scan reuses one pinned plan.
fn serve_task(
    registry: &SessionRegistry,
    plans: &mut HashMap<String, u64>,
    frame: &Frame,
    threads_override: Option<usize>,
) -> Result<Vec<f32>, LeapError> {
    let meta = &frame.meta;
    let kind = meta
        .get_str("shard")
        .ok_or_else(|| LeapError::Protocol("shard task missing \"shard\" kind".into()))?
        .to_string();
    let u0 = meta
        .get_usize("u0")
        .ok_or_else(|| LeapError::Protocol("shard task missing \"u0\"".into()))?;
    let u1 = meta
        .get_usize("u1")
        .ok_or_else(|| LeapError::Protocol("shard task missing \"u1\"".into()))?;
    let key = format!(
        "{}|{}|{}|{}|{}",
        meta.get("config").map(|c| c.to_string()).unwrap_or_default(),
        meta.get_str("model").unwrap_or(""),
        meta.get_usize("threads").map(|t| t.to_string()).unwrap_or_default(),
        meta.get_str("backend").unwrap_or(""),
        meta.get_str("storage").unwrap_or(""),
    );
    let sid = match plans.get(&key) {
        Some(&id) => id,
        None => {
            let id = match registry.open_from_meta(meta) {
                Ok(id) => id,
                // session cap: this worker has served many distinct
                // scans — evict everything and retry once
                Err(LeapError::BudgetExceeded { .. }) => {
                    for (_, id) in plans.drain() {
                        registry.close(id);
                    }
                    registry.open_from_meta(meta)?
                }
                Err(e) => return Err(e),
            };
            plans.insert(key, id);
            id
        }
    };
    let exec = registry.executor(sid).ok_or(LeapError::UnknownSession(sid))?;
    let plan = exec.shared_plan();
    let threads = threads_override.unwrap_or_else(|| plan.threads()).max(1);
    match kind.as_str() {
        "fp" => {
            let units = plan.forward_shard_units();
            if u0 > u1 || u1 > units {
                return Err(LeapError::InvalidArgument(format!(
                    "bad forward shard range {u0}..{u1} of {units} views"
                )));
            }
            let vg = plan.vg();
            let want = vg.nx * vg.ny * vg.nz;
            if frame.payload.len() != want {
                return Err(LeapError::ShapeMismatch {
                    what: "volume",
                    expected: want,
                    got: frame.payload.len(),
                });
            }
            let vol = Vol3::from_vec(vg.nx, vg.ny, vg.nz, frame.payload.clone());
            let mut sino = plan.new_sino();
            plan.forward_range_into_with_threads(&vol, &mut sino, threads, u0, u1);
            let per_view = plan.geom().nrows() * plan.geom().ncols();
            Ok(sino.data[u0 * per_view..u1 * per_view].to_vec())
        }
        "bp" => {
            let units = plan.back_shard_units();
            if u0 > u1 || u1 > units {
                return Err(LeapError::InvalidArgument(format!(
                    "bad back shard range {u0}..{u1} of {units} units"
                )));
            }
            let g = plan.geom();
            let want = g.nviews() * g.nrows() * g.ncols();
            if frame.payload.len() != want {
                return Err(LeapError::ShapeMismatch {
                    what: "sinogram",
                    expected: want,
                    got: frame.payload.len(),
                });
            }
            let sino = Sino::from_vec(g.nviews(), g.nrows(), g.ncols(), frame.payload.clone());
            // full-size partial: the range executor writes only owned
            // units, the rest stays exactly zero for the tree-reduce
            let mut vol = plan.new_vol();
            plan.back_range_into_with_threads(&sino, &mut vol, threads, u0, u1);
            Ok(vol.data)
        }
        other => Err(LeapError::Protocol(format!("unknown shard kind {other:?}"))),
    }
}
