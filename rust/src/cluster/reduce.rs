//! Deterministic tree reduction of partial volumes.
//!
//! Sharded backprojection scatters output-unit ranges to workers; each
//! worker returns a **full-size** partial volume that is zero outside
//! its owned units (the range executors write only owned outputs — see
//! `tests/range_property.rs`). The coordinator combines those partials
//! here in a **fixed, shard-count-independent order**: partials are
//! indexed by their position in the shard plan (which depends only on
//! the unit count, never on how many workers happen to be alive), and
//! [`tree_reduce`] always pairs adjacent partials `(0+1, 2+3, …)` level
//! by level. Because the pairing is a pure function of the shard count
//! and each voxel is owned by exactly one shard, the reduced volume is
//! bit-identical to in-process execution at every worker count —
//! including the degenerate single-shard plan.
//!
//! The reduction itself is plain f32 addition: for disjoint-support
//! partials every voxel sums one owned value with zeros, so no rounding
//! is introduced at any tree shape. The fixed order still matters: it
//! keeps the contract honest if a future sharding ever overlaps
//! support, and it makes the wire-level replay (retried shards land in
//! their original plan slot) order-insensitive.

/// Elementwise `dst += src`. Panics if the lengths differ — partial
/// volumes in one reduction must all come from the same plan.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "partial volumes must have one shape");
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d += *s;
    }
}

/// Reduce partial volumes in the fixed pairwise order: level by level,
/// adjacent pairs `(0,1), (2,3), …` combine (left += right) until one
/// buffer remains. `None` for an empty input. The order depends only on
/// `parts.len()` — the shard plan's size — never on which worker
/// produced which partial or when replies arrived.
pub fn tree_reduce(mut parts: Vec<Vec<f32>>) -> Option<Vec<f32>> {
    if parts.is_empty() {
        return None;
    }
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                add_into(&mut left, &right);
            }
            next.push(left);
        }
        parts = next;
    }
    parts.pop()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_reduces_to_none() {
        assert_eq!(tree_reduce(Vec::new()), None);
    }

    #[test]
    fn single_partial_passes_through_untouched() {
        let p = vec![1.0f32, -2.5, 0.0];
        assert_eq!(tree_reduce(vec![p.clone()]), Some(p));
    }

    #[test]
    fn disjoint_support_partials_reassemble_the_full_vector() {
        // 5 partials over 10 slots, uneven ownership, zeros elsewhere —
        // the shape the cluster reducer actually sees
        let full: Vec<f32> = (0..10).map(|i| (i as f32 + 1.0) * 0.5).collect();
        let cuts = [(0usize, 3usize), (3, 4), (4, 7), (7, 7), (7, 10)];
        let parts: Vec<Vec<f32>> = cuts
            .iter()
            .map(|&(a, b)| {
                let mut p = vec![0.0f32; full.len()];
                p[a..b].copy_from_slice(&full[a..b]);
                p
            })
            .collect();
        assert_eq!(tree_reduce(parts), Some(full));
    }

    #[test]
    fn order_is_fixed_by_index_not_associativity_friendly() {
        // overlapping-support inputs expose the order: with f32 rounding,
        // ((a+b)+(c+d)) generally differs from ((a+c)+(b+d)). The fixed
        // pairwise order must equal its own explicit expansion.
        let a = vec![1.0e7f32, 1.0];
        let b = vec![1.0f32, 1.0e7];
        let c = vec![-1.0e7f32, 3.0];
        let d = vec![7.0f32, -1.0e7];
        let mut ab = a.clone();
        add_into(&mut ab, &b);
        let mut cd = c.clone();
        add_into(&mut cd, &d);
        add_into(&mut ab, &cd);
        assert_eq!(tree_reduce(vec![a, b, c, d]), Some(ab));
    }

    #[test]
    fn odd_counts_carry_the_tail_up_a_level() {
        let parts = vec![vec![1.0f32], vec![2.0], vec![4.0]];
        // level 0: (1+2), 4 carried; level 1: 3+4
        assert_eq!(tree_reduce(parts), Some(vec![7.0]));
    }

    #[test]
    #[should_panic(expected = "one shape")]
    fn mismatched_lengths_panic() {
        add_into(&mut [0.0f32; 2], &[0.0f32; 3]);
    }
}
