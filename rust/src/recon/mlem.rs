//! MLEM — maximum-likelihood expectation maximization (Poisson model).
//!
//! `x ← x · Aᵀ(y / A x) / Aᵀ1`. Multiplicative, hence automatically
//! non-negative; included because LEAP advertises supporting "analytical
//! or iterative reconstruction algorithms" generally. Its fixed point
//! minimizes the same Poisson negative log-likelihood that
//! [`crate::ops::ProjectionLoss`] differentiates.
//!
//! The solver core [`mlem_op`] is generic over any
//! [`crate::ops::LinearOp`]; [`mlem`] is the concrete-projector entry
//! point (plans once, identical floats).

use crate::array::Sino;
use crate::array::Vol3;
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;

/// Run `iterations` of MLEM. `y` must be non-negative. Starts from a
/// uniform positive volume. Plans the projector once for the whole solve;
/// every `A`/`Aᵀ` runs on the persistent worker pool with slab-owned
/// backprojection (no spawn waves, no per-thread volume copies).
pub fn mlem(p: &Projector, y: &Sino, iterations: usize) -> Vol3 {
    let op = PlanOp::new(p);
    let x = mlem_op(&op, &y.data, iterations);
    Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, x)
}

/// The MLEM core on any matched [`LinearOp`] (domain layout returned).
pub fn mlem_op(op: &dyn LinearOp, y: &[f32], iterations: usize) -> Vec<f32> {
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    assert_eq!(y.len(), rn, "measurement length");
    let mut x = vec![1e-3f32; dn];
    // sensitivity Aᵀ1
    let ones = vec![1.0f32; rn];
    let mut sens = vec![0.0f32; dn];
    op.adjoint_into(&ones, &mut sens);
    let inv_sens: Vec<f32> = sens.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut ax = vec![0.0f32; rn];
    let mut ratio = vec![0.0f32; dn];
    for _ in 0..iterations {
        op.apply_into(&x, &mut ax);
        for i in 0..ax.len() {
            let denom = ax[i].max(1e-9);
            ax[i] = y[i] / denom;
        }
        op.adjoint_into(&ax, &mut ratio);
        for i in 0..x.len() {
            x[i] *= ratio[i] * inv_sens[i];
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    #[test]
    fn recovers_nonneg_phantom() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(30, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let rec = mlem(&p, &y, 40);
        let e = crate::metrics::rmse(&rec.data, &truth.data);
        assert!(e < 4e-3, "rmse {e}");
        assert!(rec.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn preserves_total_counts_roughly() {
        // EM's fixed point matches projections, so total forward mass
        // approaches total measured mass
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(20, 24, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(7.0, 0.05).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let rec = mlem(&p, &y, 30);
        let ay = p.forward(&rec);
        let ratio = ay.sum() / y.sum();
        assert!((ratio - 1.0).abs() < 0.02, "mass ratio {ratio}");
    }
}
