//! MLEM — maximum-likelihood expectation maximization (Poisson model).
//!
//! `x ← x · Aᵀ(y / A x) / Aᵀ1`. Multiplicative, hence automatically
//! non-negative; included because LEAP advertises supporting "analytical
//! or iterative reconstruction algorithms" generally.

use crate::array::Sino;
use crate::array::Vol3;
use crate::projector::Projector;

/// Run `iterations` of MLEM. `y` must be non-negative. Starts from a
/// uniform positive volume. Plans the projector once for the whole solve;
/// every `A`/`Aᵀ` runs on the persistent worker pool with slab-owned
/// backprojection (no spawn waves, no per-thread volume copies).
pub fn mlem(p: &Projector, y: &Sino, iterations: usize) -> Vol3 {
    let plan = p.plan();
    let mut x = p.new_vol();
    x.fill(1e-3);
    let sens = plan.back_ones(); // Aᵀ1
    let inv_sens: Vec<f32> =
        sens.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut ax = p.new_sino();
    for _ in 0..iterations {
        p.forward_with_plan(&plan, &x, &mut ax);
        for i in 0..ax.len() {
            let denom = ax.data[i].max(1e-9);
            ax.data[i] = y.data[i] / denom;
        }
        let ratio = plan.back(&ax);
        for i in 0..x.len() {
            x.data[i] *= ratio.data[i] * inv_sens[i];
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    #[test]
    fn recovers_nonneg_phantom() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(30, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let rec = mlem(&p, &y, 40);
        let e = crate::metrics::rmse(&rec.data, &truth.data);
        assert!(e < 4e-3, "rmse {e}");
        assert!(rec.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn preserves_total_counts_roughly() {
        // EM's fixed point matches projections, so total forward mass
        // approaches total measured mass
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(20, 24, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(7.0, 0.05).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let rec = mlem(&p, &y, 30);
        let ay = p.forward(&rec);
        let ratio = ay.sum() / y.sum();
        assert!((ratio - 1.0).abs() < 0.02, "mass ratio {ratio}");
    }
}
