//! Ramp filters for FBP/FDK with the classic apodization windows.
//!
//! The discrete ramp is built in the spatial domain (Kak & Slaney eq.
//! 3.29) and transformed — this avoids the DC bias of sampling `|ω|`
//! directly. Frequency responses are cached per (length, window).

use crate::util::fft::{fft_inplace, filter_real, next_pow2};

/// Apodization window applied on top of the ramp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    /// Pure ramp (Ram-Lak).
    RamLak,
    /// Ramp · sinc (Shepp-Logan).
    SheppLogan,
    /// Ramp · cos.
    Cosine,
    /// Ramp · (0.54 + 0.46 cos).
    Hamming,
    /// Ramp · (0.5 + 0.5 cos).
    Hann,
}

impl Window {
    pub fn parse(s: &str) -> Option<Window> {
        match s.to_ascii_lowercase().as_str() {
            "ramlak" | "ram-lak" | "ramp" => Some(Window::RamLak),
            "shepp" | "shepp-logan" | "shepplogan" => Some(Window::SheppLogan),
            "cosine" | "cos" => Some(Window::Cosine),
            "hamming" => Some(Window::Hamming),
            "hann" | "hanning" => Some(Window::Hann),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Window::RamLak => "ramlak",
            Window::SheppLogan => "shepp-logan",
            Window::Cosine => "cosine",
            Window::Hamming => "hamming",
            Window::Hann => "hann",
        }
    }

    /// Window gain at normalized frequency `f ∈ [0, 1]` (1 = Nyquist).
    fn gain(&self, f: f64) -> f64 {
        use std::f64::consts::PI;
        match self {
            Window::RamLak => 1.0,
            Window::SheppLogan => {
                if f == 0.0 {
                    1.0
                } else {
                    let x = PI * f / 2.0;
                    x.sin() / x
                }
            }
            Window::Cosine => (PI * f / 2.0).cos(),
            Window::Hamming => 0.54 + 0.46 * (PI * f).cos(),
            Window::Hann => 0.5 + 0.5 * (PI * f).cos(),
        }
    }
}

/// Frequency response of the apodized ramp for signals of length `n`
/// sampled at `pitch` mm. Returned length is `next_pow2(2n)` (linear-
/// convolution safe); multiply against an FFT and the result is already
/// scaled so that `Σ_views filtered·Δφ` reconstructs mm⁻¹ units.
pub fn ramp_response(n: usize, pitch: f64, window: Window) -> Vec<f64> {
    let nfft = next_pow2(2 * n.max(2));
    // spatial-domain band-limited ramp h[k] (Kak & Slaney):
    //   h[0] = 1/(4·du²), h[k odd] = −1/(π²k²du²), h[k even] = 0
    let mut re = vec![0.0f64; nfft];
    let mut im = vec![0.0f64; nfft];
    let du2 = pitch * pitch;
    re[0] = 1.0 / (4.0 * du2);
    for k in (1..n).step_by(2) {
        let v = -1.0 / (std::f64::consts::PI * std::f64::consts::PI * (k * k) as f64 * du2);
        re[k] = v;
        re[nfft - k] = v; // symmetric (circular) placement
    }
    fft_inplace(&mut re, &mut im, false);
    // the DFT of a real even sequence is real; keep |Re| and apodize
    let mut resp = vec![0.0f64; nfft];
    for k in 0..nfft {
        let f_norm = if k <= nfft / 2 {
            k as f64 / (nfft / 2) as f64
        } else {
            (nfft - k) as f64 / (nfft / 2) as f64
        };
        // multiply by du: discrete convolution q = du·(g ⊛ h)
        resp[k] = re[k].max(0.0) * pitch * window.gain(f_norm);
    }
    resp
}

/// The apodized ramp as a **half-spectrum** of `nfft/2 + 1` f32 samples
/// (`nfft = next_pow2(2·ncols)`), the trainable-filter parameterization
/// the tape's `FilterRows` node uses ([`crate::tape`]): the full response
/// is reconstructed by even symmetry `resp[k] = half[min(k, nfft−k)]`,
/// which holds exactly for [`ramp_response`] (the DFT of a real even
/// kernel, apodized by a window that is itself even in frequency).
/// Initializing a learnable filter from this makes iteration 0 of
/// learned FBP match the analytic ramp up to f64→f32 rounding of the
/// response samples.
pub fn ramp_half_spectrum(ncols: usize, pitch: f64, window: Window) -> Vec<f32> {
    let resp = ramp_response(ncols, pitch, window);
    let nfft = resp.len();
    (0..=nfft / 2).map(|k| resp[k] as f32).collect()
}

/// Filter every row of a sinogram view in place: `rows` of length `ncols`,
/// response from [`ramp_response`].
pub fn filter_rows(rows: &mut [f32], ncols: usize, resp: &[f64]) {
    assert_eq!(rows.len() % ncols, 0);
    let mut out = vec![0.0f32; ncols];
    for row in rows.chunks_mut(ncols) {
        filter_real(row, resp, &mut out);
        row.copy_from_slice(&out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_is_rampish() {
        let r = ramp_response(64, 1.0, Window::RamLak);
        // rises from ~0 at DC to max near Nyquist
        assert!(r[0] < r[8]);
        assert!(r[8] < r[32]);
        let peak = r.iter().cloned().fold(0.0, f64::max);
        assert!((peak - r[r.len() / 2]).abs() / peak < 0.05, "peak near Nyquist");
    }

    #[test]
    fn windows_attenuate_high_freq() {
        let n = 64;
        let ram = ramp_response(n, 1.0, Window::RamLak);
        for w in [Window::SheppLogan, Window::Cosine, Window::Hamming, Window::Hann] {
            let r = ramp_response(n, 1.0, w);
            let nyq = r.len() / 2;
            assert!(r[nyq] < ram[nyq], "{} should attenuate Nyquist", w.name());
            // all windows ~agree at low frequency
            assert!((r[2] - ram[2]).abs() / ram[2] < 0.15, "{}", w.name());
        }
    }

    #[test]
    fn pitch_scaling() {
        // halving du doubles the ramp amplitude at fixed normalized freq
        // (response includes one du factor for the convolution and 1/du²
        // in the kernel → net 1/du)
        let a = ramp_response(64, 1.0, Window::RamLak);
        let b = ramp_response(64, 0.5, Window::RamLak);
        let k = a.len() / 4;
        assert!((b[k] / a[k] - 2.0).abs() < 0.05, "ratio {}", b[k] / a[k]);
    }

    #[test]
    fn filter_rows_removes_dc() {
        let ncols = 32;
        let mut rows = vec![1.0f32; 2 * ncols];
        let resp = ramp_response(ncols, 1.0, Window::RamLak);
        filter_rows(&mut rows, ncols, &resp);
        // ramp of a constant is ~0 away from the edges
        for c in 12..20 {
            assert!(rows[c].abs() < 0.02, "col {c}: {}", rows[c]);
        }
    }

    #[test]
    fn parse_windows() {
        assert_eq!(Window::parse("hann"), Some(Window::Hann));
        assert_eq!(Window::parse("Ram-Lak"), Some(Window::RamLak));
        assert_eq!(Window::parse("nope"), None);
    }
}
