//! Analytic reconstruction: FBP (parallel), fan-beam FBP and FDK
//! (circular cone-beam), with pixel-driven interpolating backprojection.
//!
//! The pixel-driven backprojector here is the classic *unmatched*
//! backprojection used by analytic algorithms (and by most reconstruction
//! packages, as the paper notes §2.1) — it also serves as the deliberately
//! unmatched operator in the matched-vs-unmatched stability experiment
//! (`examples/matched_vs_unmatched.rs`).

use crate::array::{Sino, Vol3};
use crate::geometry::{ConeBeam, DetectorShape, FanBeam, ParallelBeam, VolumeGeometry};
use crate::util::pool::{parallel_chunks, ParWriter};

use super::filters::{filter_rows, ramp_response, Window};

/// Pixel-driven backprojection for parallel beam: for every voxel,
/// linearly interpolate each view's (filtered) row at `u = x·û` and
/// accumulate. `scale` multiplies the result (usually `Δφ`).
pub fn backproject_pixel_parallel(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    sino: &Sino,
    scale: f64,
    threads: usize,
) -> Vol3 {
    let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
    let nviews = g.angles.len();
    let ncols = g.ncols;
    let out = ParWriter::new(&mut vol.data);
    // parallel over z-slices (each worker owns whole slices)
    parallel_chunks(vg.nz, threads, |k0, k1| {
        for k in k0..k1 {
            let z = vg.z(k);
            // nearest detector row for this slice (linear interp over rows)
            let fr = g.row_of_v(z);
            let r0 = fr.floor() as i64;
            let wr1 = (fr - r0 as f64) as f32;
            let wr0 = 1.0 - wr1;
            for view in 0..nviews {
                let (s, c) = g.angles[view].sin_cos();
                let vdata = sino.view(view);
                let row0 = if r0 >= 0 && (r0 as usize) < g.nrows {
                    Some(&vdata[r0 as usize * ncols..(r0 as usize + 1) * ncols])
                } else {
                    None
                };
                let r1 = r0 + 1;
                let row1 = if r1 >= 0 && (r1 as usize) < g.nrows {
                    Some(&vdata[r1 as usize * ncols..(r1 as usize + 1) * ncols])
                } else {
                    None
                };
                if row0.is_none() && row1.is_none() {
                    continue;
                }
                let sample = |row: Option<&[f32]>, w: f32, fu: f64| -> f32 {
                    let Some(row) = row else { return 0.0 };
                    if w == 0.0 {
                        return 0.0;
                    }
                    let i0 = fu.floor() as i64;
                    let wu1 = (fu - i0 as f64) as f32;
                    let wu0 = 1.0 - wu1;
                    let mut acc = 0.0;
                    if i0 >= 0 && (i0 as usize) < row.len() {
                        acc += wu0 * row[i0 as usize];
                    }
                    if i0 + 1 >= 0 && ((i0 + 1) as usize) < row.len() {
                        acc += wu1 * row[(i0 + 1) as usize];
                    }
                    w * acc
                };
                for j in 0..vg.ny {
                    let y = vg.y(j);
                    for i in 0..vg.nx {
                        let x = vg.x(i);
                        let u = x * c + y * s;
                        let fu = g.col_of_u(u);
                        let q = sample(row0, wr0, fu) + sample(row1, wr1, fu);
                        out.add((k * vg.ny + j) * vg.nx + i, q * scale as f32);
                    }
                }
            }
        }
    });
    vol
}

/// 2-D/3-D parallel-beam FBP. Angles may span 180° or 360° (values are
/// averaged accordingly through `Δφ = range/nviews`).
pub fn fbp_parallel(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    sino: &Sino,
    window: Window,
    threads: usize,
) -> Vol3 {
    let mut filtered = sino.clone();
    let resp = ramp_response(g.ncols, g.du, window);
    filter_rows(&mut filtered.data, g.ncols, &resp);
    // Δφ for (possibly non-equispaced) angles: mean gap over the arc,
    // assuming a half-turn parameterization for the classic formula
    let dphi = mean_angle_gap(&g.angles);
    // a full 360° parallel scan measures every line twice
    let arc: f64 = dphi * g.angles.len() as f64;
    let dup = if arc > 1.5 * std::f64::consts::PI { 2.0 } else { 1.0 };
    backproject_pixel_parallel(vg, g, &filtered, dphi / dup, threads)
}

fn mean_angle_gap(angles: &[f64]) -> f64 {
    if angles.len() < 2 {
        return std::f64::consts::PI / angles.len().max(1) as f64;
    }
    let mut gaps = Vec::with_capacity(angles.len() - 1);
    for w in angles.windows(2) {
        gaps.push((w[1] - w[0]).abs());
    }
    gaps.iter().sum::<f64>() / gaps.len() as f64
}

/// Fan-beam FBP (flat detector): cosine-weight, ramp-filter, backproject
/// with `sod²/U²` distance weighting.
pub fn fbp_fan(
    vg: &VolumeGeometry,
    g: &FanBeam,
    sino: &Sino,
    window: Window,
    threads: usize,
) -> Vol3 {
    assert_eq!(vg.nz, 1, "fan FBP expects a single-slice volume");
    let mut filtered = sino.clone();
    // pre-weight: g'(u) = g(u)·sdd/√(sdd²+u²)
    for view in 0..filtered.nviews {
        for colidx in 0..filtered.ncols {
            let u = g.u(colidx);
            let w = g.sdd / (g.sdd * g.sdd + u * u).sqrt();
            let v = filtered.at(view, 0, colidx) * w as f32;
            *filtered.at_mut(view, 0, colidx) = v;
        }
    }
    let resp = ramp_response(g.ncols, g.du, window);
    filter_rows(&mut filtered.data, g.ncols, &resp);
    let dphi = mean_angle_gap(&g.angles);
    let arc = dphi * g.angles.len() as f64;
    let dup = if arc > 1.5 * std::f64::consts::PI { 2.0 } else { 1.0 };

    let mut vol = Vol3::zeros(vg.nx, vg.ny, 1);
    let nviews = g.angles.len();
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(vg.ny, threads, |j0, j1| {
        // each worker owns voxel rows j0..j1
        for j in j0..j1 {
            let y = vg.y(j);
            for i in 0..vg.nx {
                let x = vg.x(i);
                let mut acc = 0.0f64;
                for view in 0..nviews {
                    let (sphi, cphi) = g.angles[view].sin_cos();
                    // distance along the central axis from source to voxel
                    let t = g.sod - (x * cphi + y * sphi);
                    if t <= 1e-6 {
                        continue;
                    }
                    // detector coordinate of the voxel
                    let uperp = -x * sphi + y * cphi;
                    let u = g.sdd * uperp / t;
                    let fu = g.col_of_u(u);
                    let i0 = fu.floor() as i64;
                    let w1 = fu - i0 as f64;
                    let row = filtered.view(view);
                    let mut q = 0.0f64;
                    if i0 >= 0 && (i0 as usize) < row.len() {
                        q += (1.0 - w1) * row[i0 as usize] as f64;
                    }
                    if i0 + 1 >= 0 && ((i0 + 1) as usize) < row.len() {
                        q += w1 * row[(i0 + 1) as usize] as f64;
                    }
                    acc += q * (g.sod * g.sod) / (t * t);
                }
                out.set(j * vg.nx + i, (acc * dphi / dup * g.sdd / g.sod) as f32);
            }
        }
    });
    vol
}

/// FDK reconstruction for circular flat-detector cone-beam: row/col
/// cosine weighting, per-row ramp filtering, distance-weighted
/// backprojection (Feldkamp, Davis & Kress 1984).
pub fn fdk(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    sino: &Sino,
    window: Window,
    threads: usize,
) -> Vol3 {
    assert_eq!(g.shape, DetectorShape::Flat, "FDK implemented for flat detectors");
    let mut filtered = sino.clone();
    for view in 0..filtered.nviews {
        for r in 0..filtered.nrows {
            let v = g.v(r);
            for c in 0..filtered.ncols {
                let u = g.u(c);
                let w = g.sdd / (g.sdd * g.sdd + u * u + v * v).sqrt();
                let val = filtered.at(view, r, c) * w as f32;
                *filtered.at_mut(view, r, c) = val;
            }
        }
    }
    let resp = ramp_response(g.ncols, g.du, window);
    filter_rows(&mut filtered.data, g.ncols, &resp);
    let dphi = mean_angle_gap(&g.angles);
    let arc = dphi * g.angles.len() as f64;
    let dup = if arc > 1.5 * std::f64::consts::PI { 2.0 } else { 1.0 };

    let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
    let nviews = g.angles.len();
    let ncols = g.ncols;
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(vg.nz, threads, |k0, k1| {
        // each worker owns whole z-slices k0..k1
        for k in k0..k1 {
            let z = vg.z(k);
            for j in 0..vg.ny {
                let y = vg.y(j);
                for i in 0..vg.nx {
                    let x = vg.x(i);
                    let mut acc = 0.0f64;
                    for view in 0..nviews {
                        let (sphi, cphi) = g.angles[view].sin_cos();
                        let t = g.sod - (x * cphi + y * sphi);
                        if t <= 1e-6 {
                            continue;
                        }
                        let uperp = -x * sphi + y * cphi;
                        let u = g.sdd * uperp / t;
                        let v = g.sdd * z / t;
                        let fu = g.col_of_u(u);
                        let fv = g.row_of_v(v);
                        let i0 = fu.floor() as i64;
                        let r0 = fv.floor() as i64;
                        let wu1 = fu - i0 as f64;
                        let wv1 = fv - r0 as f64;
                        let vdata = filtered.view(view);
                        let mut q = 0.0f64;
                        for (rr, wv) in [(r0, 1.0 - wv1), (r0 + 1, wv1)] {
                            if rr < 0 || rr as usize >= g.nrows || wv == 0.0 {
                                continue;
                            }
                            let row = &vdata[rr as usize * ncols..(rr as usize + 1) * ncols];
                            for (cc, wu) in [(i0, 1.0 - wu1), (i0 + 1, wu1)] {
                                if cc < 0 || cc as usize >= ncols {
                                    continue;
                                }
                                q += wv * wu * row[cc as usize] as f64;
                            }
                        }
                        acc += q * (g.sod * g.sod) / (t * t);
                    }
                    out.set((k * vg.ny + j) * vg.nx + i, (acc * dphi / dup * g.sdd / g.sod) as f32);
                }
            }
        }
    });
    vol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{angles_deg, Geometry};
    use crate::phantom::{shepp::shepp_logan_2d, Phantom, Shape};
    use crate::projector::{Model, Projector};

    /// FBP of an analytic disk sinogram recovers the disk's attenuation.
    #[test]
    fn fbp_parallel_recovers_disk_value() {
        let mu = 0.02f64;
        let ph = Phantom::new(vec![Shape::ellipse2d(0.0, 0.0, 12.0, 12.0, 0.0, mu)]);
        let g = ParallelBeam::standard_2d(90, 64, 1.0);
        let sino = ph.project(&Geometry::Parallel(g.clone()));
        let vg = VolumeGeometry::slice2d(48, 48, 1.0);
        let rec = fbp_parallel(&vg, &g, &sino, Window::RamLak, 1);
        let center = rec.at(24, 24, 0) as f64;
        assert!((center - mu).abs() < 0.15 * mu, "center {center} vs {mu}");
        // outside the disk ≈ 0
        let outside = rec.at(4, 24, 0) as f64;
        assert!(outside.abs() < 0.2 * mu, "outside {outside}");
    }

    #[test]
    fn fbp_reduces_error_vs_backprojection_only() {
        let ph = shepp_logan_2d(20.0, 0.02);
        let g = ParallelBeam::standard_2d(120, 64, 0.8);
        let sino = ph.project(&Geometry::Parallel(g.clone()));
        let vg = VolumeGeometry::slice2d(48, 48, 0.85);
        let truth = ph.rasterize(&vg, 2);
        let rec = fbp_parallel(&vg, &g, &sino, Window::Hann, 1);
        let blur = backproject_pixel_parallel(&vg, &g, &sino, 1.0, 1);
        let e_fbp = crate::metrics::rmse(&rec.data, &truth.data);
        let e_blur = crate::metrics::rmse(&blur.data, &truth.data);
        assert!(e_fbp < 0.3 * e_blur, "fbp {e_fbp} vs blur {e_blur}");
    }

    #[test]
    fn fbp_fan_recovers_disk_value() {
        let mu = 0.02f64;
        let ph = Phantom::new(vec![Shape::ellipse2d(0.0, 0.0, 12.0, 12.0, 0.0, mu)]);
        let g = FanBeam::standard(180, 96, 1.0, 120.0, 240.0);
        let sino = ph.project(&Geometry::Fan(g.clone()));
        let vg = VolumeGeometry::slice2d(48, 48, 1.0);
        let rec = fbp_fan(&vg, &g, &sino, Window::RamLak, 1);
        let center = rec.at(24, 24, 0) as f64;
        assert!((center - mu).abs() < 0.2 * mu, "center {center} vs {mu}");
    }

    #[test]
    fn fdk_central_slice_recovers_disk() {
        let mu = 0.02f64;
        // tall cylinder so the central slice is fan-like
        let ph = Phantom::new(vec![Shape::Ellipsoid {
            center: [0.0; 3],
            axes: [10.0, 10.0, 40.0],
            phi: 0.0,
            density: mu,
        }]);
        let g = ConeBeam::standard(120, 16, 64, 1.0, 1.0, 100.0, 200.0);
        let sino = ph.project(&Geometry::Cone(g.clone()));
        let vg = VolumeGeometry { nx: 32, ny: 32, nz: 4, vx: 1.0, vy: 1.0, vz: 1.0, cx: 0.0, cy: 0.0, cz: 0.0 };
        let rec = fdk(&vg, &g, &sino, Window::RamLak, 2);
        let center = rec.at(16, 16, 2) as f64;
        assert!((center - mu).abs() < 0.25 * mu, "center {center} vs {mu}");
    }

    #[test]
    fn limited_angle_fbp_has_artifacts() {
        // the premise of the Figure-3 experiment: 60° of data → much worse
        // reconstruction than 180°
        let ph = shepp_logan_2d(20.0, 0.02);
        let g_full = ParallelBeam::standard_2d(120, 64, 0.8);
        let g_limited = ParallelBeam {
            angles: angles_deg(40, 0.0, 60.0),
            ..g_full.clone()
        };
        let vg = VolumeGeometry::slice2d(48, 48, 0.85);
        let truth = ph.rasterize(&vg, 2);
        let s_full = ph.project(&Geometry::Parallel(g_full.clone()));
        let s_lim = ph.project(&Geometry::Parallel(g_limited.clone()));
        let r_full = fbp_parallel(&vg, &g_full, &s_full, Window::Hann, 1);
        let r_lim = fbp_parallel(&vg, &g_limited, &s_lim, Window::Hann, 1);
        let e_full = crate::metrics::rmse(&r_full.data, &truth.data);
        let e_lim = crate::metrics::rmse(&r_lim.data, &truth.data);
        assert!(e_lim > 2.0 * e_full, "limited {e_lim} vs full {e_full}");
    }

    #[test]
    fn pixel_backprojector_is_not_matched() {
        // sanity check for the matched-vs-unmatched experiment: the
        // pixel-driven backprojector deliberately violates the adjoint
        // identity that Projector::back satisfies
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = ParallelBeam::standard_2d(10, 24, 1.0);
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
        let mut rng = crate::util::rng::Rng::new(2);
        let mut x = p.new_vol();
        let mut y = p.new_sino();
        rng.fill_uniform(&mut x.data, -1.0, 1.0);
        rng.fill_uniform(&mut y.data, -1.0, 1.0);
        let lhs = crate::util::dot_f64(&p.forward(&x).data, &y.data);
        let unmatched = backproject_pixel_parallel(&vg, &g, &y, 1.0, 1);
        let rhs = crate::util::dot_f64(&x.data, &unmatched.data);
        let gap = (lhs - rhs).abs() / lhs.abs().max(1e-12);
        assert!(gap > 1e-3, "pixel backprojector unexpectedly matched: {gap}");
    }
}
