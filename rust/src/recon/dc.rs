//! Data-consistency refinement and sinogram completion — the paper's §3–4
//! inference-time pipeline (Figure 2/3).
//!
//! Given a limited-angle measurement `y` (views with `mask = 1`) and a
//! prior/predicted image `x_pred` from an inference model:
//!
//! 1. **Sinogram completion** (Anirudh et al. 2018): forward-project
//!    `x_pred` and splice its projections into the *missing* views,
//!    keeping the measured data where available.
//! 2. **Iterative data-consistency refinement** (Zhou et al. 2021; Liu et
//!    al. 2022): starting from `x_pred`, minimize `‖M(Ax − y)‖²` (+ small
//!    TV) so the result agrees with what was actually measured while the
//!    prior fills the null space — `argmin ‖Ax − y‖²` per the paper's §3.
//!
//! The headline claim reproduced in `examples/limited_angle_dc.rs`: this
//! refinement *improves* PSNR/SSIM over the raw prediction.

use crate::array::{Sino, Vol3};
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;

use super::sirt::{sirt_op, SirtOpts};

/// A limited-angle acquisition mask: 1 = measured view, 0 = missing.
#[derive(Clone, Debug)]
pub struct ViewMask {
    pub weights: Vec<f32>,
}

impl ViewMask {
    /// Keep a contiguous arc `[first, first + count)` of views.
    pub fn contiguous(nviews: usize, first: usize, count: usize) -> ViewMask {
        let weights = (0..nviews)
            .map(|v| {
                let inside = v >= first && v < first + count;
                if inside {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        ViewMask { weights }
    }

    /// Keep every `stride`-th view (few-view CT).
    pub fn strided(nviews: usize, stride: usize) -> ViewMask {
        ViewMask { weights: (0..nviews).map(|v| if v % stride == 0 { 1.0 } else { 0.0 }).collect() }
    }

    pub fn measured_count(&self) -> usize {
        self.weights.iter().filter(|&&w| w > 0.0).count()
    }

    /// Zero out the missing views of a sinogram (what the scanner gives us).
    pub fn apply(&self, sino: &mut Sino) {
        super::sirt::apply_view_mask(sino, &self.weights);
    }
}

/// Sinogram completion: measured views from `y`, missing views from
/// `A·x_pred`.
pub fn complete_sinogram(p: &Projector, y: &Sino, mask: &ViewMask, x_pred: &Vol3) -> Sino {
    let pred = p.forward(x_pred);
    let mut out = y.clone();
    splice_missing_views(&mut out.data, &pred.data, mask, out.nrows * out.ncols);
    out
}

/// [`complete_sinogram`] on any matched [`LinearOp`]: measured views
/// from `y` (range layout), missing views from `A·x_pred`.
pub fn complete_sinogram_op(
    op: &dyn LinearOp,
    y: &[f32],
    mask: &ViewMask,
    x_pred: &[f32],
) -> Vec<f32> {
    let rn = op.range_shape().numel();
    assert_eq!(y.len(), rn, "measurement length");
    let per_view = rn / op.range_shape().0[0].max(1);
    let pred = op.apply(x_pred);
    let mut out = y.to_vec();
    splice_missing_views(&mut out, &pred, mask, per_view);
    out
}

/// Overwrite the masked-out view blocks of `out` with `pred`'s.
fn splice_missing_views(out: &mut [f32], pred: &[f32], mask: &ViewMask, per_view: usize) {
    for (view, &w) in mask.weights.iter().enumerate() {
        if w == 0.0 {
            out[view * per_view..(view + 1) * per_view]
                .copy_from_slice(&pred[view * per_view..(view + 1) * per_view]);
        }
    }
}

/// Options for [`refine`].
#[derive(Clone, Debug)]
pub struct DcOpts {
    /// SIRT iterations of masked data-consistency.
    pub iterations: usize,
    pub lambda: f32,
    /// Optional small TV smoothing weight applied after refinement
    /// (0 disables).
    pub tv_weight: f32,
    pub tv_iters: usize,
}

impl Default for DcOpts {
    fn default() -> Self {
        DcOpts { iterations: 20, lambda: 1.0, tv_weight: 0.0, tv_iters: 10 }
    }
}

/// Iterative data-consistency refinement: start from the prediction and
/// pull it toward agreement with the measured views. Plans once and runs
/// [`refine_op`] — identical floats to the historical concrete path.
pub fn refine(p: &Projector, y: &Sino, mask: &ViewMask, x_pred: &Vol3, opts: &DcOpts) -> Vol3 {
    let op = PlanOp::new(p);
    let out = refine_op(&op, &y.data, mask, &x_pred.data, opts);
    Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, out)
}

/// [`refine`] on any matched [`LinearOp`]: masked SIRT from the
/// prediction, plus an optional small TV smoothing.
pub fn refine_op(
    op: &dyn LinearOp,
    y: &[f32],
    mask: &ViewMask,
    x_pred: &[f32],
    opts: &DcOpts,
) -> Vec<f32> {
    let (mut out, _) = sirt_op(
        op,
        y,
        x_pred,
        &SirtOpts {
            iterations: opts.iterations,
            lambda: opts.lambda,
            nonneg: true,
            view_mask: Some(mask.weights.clone()),
            track_residual: false,
        },
    );
    if opts.tv_weight > 0.0 {
        let d = op.domain_shape().0;
        super::fista_tv::tv_prox_slices(&mut out, d[0], d[1], d[2], opts.tv_weight, opts.tv_iters);
    }
    out
}

/// Residual of the measured views only: `‖M(Ax − y)‖₂ / ‖M y‖₂` — the
/// data-consistency metric the paper's pipeline monitors.
pub fn data_consistency_error(p: &Projector, y: &Sino, mask: &ViewMask, x: &Vol3) -> f64 {
    let ax = p.forward(x);
    masked_relative_residual(&ax.data, &y.data, mask, y.nrows * y.ncols)
}

/// [`data_consistency_error`] on any matched [`LinearOp`].
pub fn data_consistency_error_op(op: &dyn LinearOp, y: &[f32], mask: &ViewMask, x: &[f32]) -> f64 {
    let ax = op.apply(x);
    let per_view = op.range_shape().numel() / op.range_shape().0[0].max(1);
    masked_relative_residual(&ax, y, mask, per_view)
}

fn masked_relative_residual(ax: &[f32], y: &[f32], mask: &ViewMask, per_view: usize) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (view, &w) in mask.weights.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        for i in view * per_view..(view + 1) * per_view {
            let d = (ax[i] - y[i]) as f64;
            num += d * d;
            den += (y[i] as f64) * (y[i] as f64);
        }
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::{luggage, shepp::shepp_logan_2d};
    use crate::projector::Model;
    use crate::recon::fbp::fbp_parallel;
    use crate::recon::filters::Window;

    fn setup(nviews: usize) -> (Projector, Vol3, Sino, ParallelBeam) {
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let g = ParallelBeam::standard_2d(nviews, 48, 1.0);
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
        let truth = shepp_logan_2d(14.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        (p, truth, y, g)
    }

    #[test]
    fn mask_constructors() {
        let m = ViewMask::contiguous(10, 2, 3);
        assert_eq!(m.measured_count(), 3);
        assert_eq!(m.weights[1], 0.0);
        assert_eq!(m.weights[2], 1.0);
        assert_eq!(m.weights[4], 1.0);
        assert_eq!(m.weights[5], 0.0);
        let s = ViewMask::strided(10, 3);
        assert_eq!(s.measured_count(), 4); // views 0,3,6,9
    }

    #[test]
    fn completion_keeps_measured_fills_missing() {
        let (p, truth, y, _) = setup(12);
        let mask = ViewMask::contiguous(12, 0, 4);
        let mut y_masked = y.clone();
        mask.apply(&mut y_masked);
        // prior: blurred truth
        let mut prior = truth.clone();
        for v in prior.data.iter_mut() {
            *v *= 0.8;
        }
        let completed = complete_sinogram(&p, &y_masked, &mask, &prior);
        // measured views identical to y
        for view in 0..4 {
            assert_eq!(completed.view(view), y_masked.view(view));
        }
        // missing views come from the prior's forward projection
        let pred = p.forward(&prior);
        for view in 4..12 {
            assert_eq!(completed.view(view), pred.view(view));
        }
    }

    #[test]
    fn refinement_improves_prediction_shepp() {
        // the Figure-3 shape: imperfect prediction + DC refinement → better
        let (p, truth, y, _g) = setup(36);
        let mask = ViewMask::contiguous(36, 0, 12); // 60° of 180°
        // "prediction": scaled + slightly blurred truth (imperfect prior)
        let mut pred = truth.clone();
        for v in pred.data.iter_mut() {
            *v *= 0.85;
        }
        let refined = refine(&p, &y, &mask, &pred, &DcOpts { iterations: 30, ..Default::default() });
        let psnr_pred = crate::metrics::psnr(&pred.data, &truth.data, None);
        let psnr_ref = crate::metrics::psnr(&refined.data, &truth.data, None);
        assert!(
            psnr_ref > psnr_pred + 1.0,
            "refinement should improve PSNR: {psnr_pred} → {psnr_ref}"
        );
        // and data consistency improves too
        let dc_pred = data_consistency_error(&p, &y, &mask, &pred);
        let dc_ref = data_consistency_error(&p, &y, &mask, &refined);
        assert!(dc_ref < dc_pred, "{dc_pred} → {dc_ref}");
    }

    #[test]
    fn refinement_improves_luggage_fbp_prior() {
        // end-to-end miniature of the paper's experiment on one bag:
        // limited-angle FBP prior → DC refinement improves PSNR
        let vg = VolumeGeometry::slice2d(32, 32, 12.0);
        let g = ParallelBeam::standard_2d(48, 48, 12.0);
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
        let bag = luggage::bag(17, &luggage::LuggageParams::default());
        let truth = bag.rasterize(&vg, 2);
        let y = p.forward(&truth);
        let mask = ViewMask::contiguous(48, 0, 16); // 60° of 180°
        let mut y_masked = y.clone();
        mask.apply(&mut y_masked);
        // prior: FBP on the limited data only (classic ill-posed input)
        let g_lim = ParallelBeam {
            angles: g.angles[0..16].to_vec(),
            ..g.clone()
        };
        let sino_lim = Sino::from_vec(16, 1, 48, y.data[..16 * 48].to_vec());
        let prior = fbp_parallel(&vg, &g_lim, &sino_lim, Window::Hann, 1);
        let refined = refine(
            &p,
            &y_masked,
            &mask,
            &prior,
            &DcOpts { iterations: 40, tv_weight: 1e-4, tv_iters: 10, ..Default::default() },
        );
        let psnr_prior = crate::metrics::psnr(&prior.data, &truth.data, None);
        let psnr_ref = crate::metrics::psnr(&refined.data, &truth.data, None);
        assert!(
            psnr_ref > psnr_prior,
            "DC refinement should improve the FBP prior: {psnr_prior} → {psnr_ref}"
        );
    }
}
