//! OS-SART: ordered-subsets SART — SIRT-style updates over interleaved
//! view subsets for much faster early convergence.
//!
//! With `subsets = 1` this degenerates to (masked) SIRT. Subsets are
//! chosen by the interleaving `view % subsets == s`, the standard
//! maximal-angular-separation ordering for equiangular scans. Each
//! subset sweep is exactly a [`crate::ops::RowMasked`] application of
//! the operator — the core below keeps the masks as flat weights so one
//! operator serves every subset.
//!
//! The solver core [`os_sart_op`] is generic over any
//! [`crate::ops::LinearOp`]; [`os_sart`] is the concrete-projector
//! entry point (plans once, identical floats).

use crate::array::{Sino, Vol3};
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;

use super::sirt::apply_view_mask_flat;

/// Options for [`os_sart`].
#[derive(Clone, Debug)]
pub struct OsSartOpts {
    pub iterations: usize,
    pub subsets: usize,
    pub lambda: f32,
    pub nonneg: bool,
}

impl Default for OsSartOpts {
    fn default() -> Self {
        OsSartOpts { iterations: 10, subsets: 8, lambda: 1.0, nonneg: true }
    }
}

/// Run OS-SART from `x0`. Plans the projector once for the whole solve;
/// every subset sweep reuses the cached per-view geometry. The many small
/// masked applications per iteration are exactly the workload the
/// persistent worker pool removes the spawn wave from.
pub fn os_sart(p: &Projector, y: &Sino, x0: &Vol3, opts: &OsSartOpts) -> Vol3 {
    let op = PlanOp::new(p);
    let x = os_sart_op(&op, &y.data, &x0.data, opts);
    Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, x)
}

/// The OS-SART core on any matched [`LinearOp`] (domain layout
/// returned).
pub fn os_sart_op(op: &dyn LinearOp, y: &[f32], x0: &[f32], opts: &OsSartOpts) -> Vec<f32> {
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    let nviews = op.range_shape().0[0];
    let per_view = if nviews > 0 { rn / nviews } else { 0 };
    assert_eq!(y.len(), rn, "measurement length");
    assert_eq!(x0.len(), dn, "initial volume length");
    let subsets = opts.subsets.clamp(1, nviews.max(1));
    let mut x = x0.to_vec();

    // per-subset normalizations
    let ones_vol = vec![1.0f32; dn];
    let mut row_sum_full = vec![0.0f32; rn];
    op.apply_into(&ones_vol, &mut row_sum_full);
    let mut subset_masks: Vec<Vec<f32>> = Vec::with_capacity(subsets);
    let mut inv_cols: Vec<Vec<f32>> = Vec::with_capacity(subsets);
    let mut col = vec![0.0f32; dn];
    for s in 0..subsets {
        let mask: Vec<f32> =
            (0..nviews).map(|v| if v % subsets == s { 1.0 } else { 0.0 }).collect();
        let mut ones = vec![1.0f32; rn];
        apply_view_mask_flat(&mut ones, &mask, per_view);
        op.adjoint_into(&ones, &mut col);
        inv_cols.push(col.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect());
        subset_masks.push(mask);
    }
    let inv_row: Vec<f32> =
        row_sum_full.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();

    let mut ax = vec![0.0f32; rn];
    let mut grad = vec![0.0f32; dn];
    for _ in 0..opts.iterations {
        for s in 0..subsets {
            op.apply_into(&x, &mut ax);
            for i in 0..ax.len() {
                ax[i] = (y[i] - ax[i]) * inv_row[i];
            }
            apply_view_mask_flat(&mut ax, &subset_masks[s], per_view);
            op.adjoint_into(&ax, &mut grad);
            let inv_col = &inv_cols[s];
            for i in 0..x.len() {
                let mut v = x[i] + opts.lambda * inv_col[i] * grad[i];
                if opts.nonneg && v < 0.0 {
                    v = 0.0;
                }
                x[i] = v;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;
    use crate::recon::sirt::{sirt, SirtOpts};

    #[test]
    fn faster_than_sirt_per_full_pass() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(32, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let x0 = p.new_vol();
        // 3 OS-SART iterations with 8 subsets vs 3 SIRT iterations
        let os = os_sart(&p, &y, &x0, &OsSartOpts { iterations: 3, subsets: 8, ..Default::default() });
        let si = sirt(&p, &y, &x0, &SirtOpts { iterations: 3, ..Default::default() });
        let e_os = crate::metrics::rmse(&os.data, &truth.data);
        let e_si = crate::metrics::rmse(&si.vol.data, &truth.data);
        assert!(e_os < e_si, "os-sart {e_os} vs sirt {e_si}");
    }

    #[test]
    fn one_subset_equals_sirt() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let x0 = p.new_vol();
        let os = os_sart(&p, &y, &x0, &OsSartOpts { iterations: 4, subsets: 1, lambda: 0.9, nonneg: true });
        let si = sirt(&p, &y, &x0, &SirtOpts { iterations: 4, lambda: 0.9, nonneg: true, ..Default::default() });
        for i in 0..os.len() {
            assert!((os.data[i] - si.vol.data[i]).abs() < 1e-5, "idx {i}");
        }
    }
}
