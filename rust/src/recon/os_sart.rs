//! OS-SART: ordered-subsets SART — SIRT-style updates over interleaved
//! view subsets for much faster early convergence.
//!
//! With `subsets = 1` this degenerates to (masked) SIRT. Subsets are
//! chosen by the interleaving `view % subsets == s`, the standard
//! maximal-angular-separation ordering for equiangular scans.

use crate::array::{Sino, Vol3};
use crate::projector::Projector;

/// Options for [`os_sart`].
#[derive(Clone, Debug)]
pub struct OsSartOpts {
    pub iterations: usize,
    pub subsets: usize,
    pub lambda: f32,
    pub nonneg: bool,
}

impl Default for OsSartOpts {
    fn default() -> Self {
        OsSartOpts { iterations: 10, subsets: 8, lambda: 1.0, nonneg: true }
    }
}

/// Run OS-SART from `x0`. Plans the projector once for the whole solve;
/// every subset sweep reuses the cached per-view geometry. The many small
/// masked applications per iteration are exactly the workload the
/// persistent worker pool removes the spawn wave from.
pub fn os_sart(p: &Projector, y: &Sino, x0: &Vol3, opts: &OsSartOpts) -> Vol3 {
    let plan = p.plan();
    let nviews = y.nviews;
    let subsets = opts.subsets.clamp(1, nviews);
    let mut x = x0.clone();

    // per-subset normalizations
    let row_sum_full = plan.forward_ones();
    let mut subset_masks: Vec<Vec<f32>> = Vec::with_capacity(subsets);
    let mut inv_cols: Vec<Vec<f32>> = Vec::with_capacity(subsets);
    for s in 0..subsets {
        let mask: Vec<f32> =
            (0..nviews).map(|v| if v % subsets == s { 1.0 } else { 0.0 }).collect();
        let mut ones = p.new_sino();
        ones.fill(1.0);
        super::sirt::apply_view_mask(&mut ones, &mask);
        let col = plan.back(&ones);
        inv_cols.push(col.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect());
        subset_masks.push(mask);
    }
    let inv_row: Vec<f32> =
        row_sum_full.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();

    let mut ax = p.new_sino();
    for _ in 0..opts.iterations {
        for s in 0..subsets {
            p.forward_with_plan(&plan, &x, &mut ax);
            for i in 0..ax.len() {
                ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
            }
            super::sirt::apply_view_mask(&mut ax, &subset_masks[s]);
            let grad = plan.back(&ax);
            let inv_col = &inv_cols[s];
            for i in 0..x.len() {
                let mut v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
                if opts.nonneg && v < 0.0 {
                    v = 0.0;
                }
                x.data[i] = v;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;
    use crate::recon::sirt::{sirt, SirtOpts};

    #[test]
    fn faster_than_sirt_per_full_pass() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(32, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let x0 = p.new_vol();
        // 3 OS-SART iterations with 8 subsets vs 3 SIRT iterations
        let os = os_sart(&p, &y, &x0, &OsSartOpts { iterations: 3, subsets: 8, ..Default::default() });
        let si = sirt(&p, &y, &x0, &SirtOpts { iterations: 3, ..Default::default() });
        let e_os = crate::metrics::rmse(&os.data, &truth.data);
        let e_si = crate::metrics::rmse(&si.vol.data, &truth.data);
        assert!(e_os < e_si, "os-sart {e_os} vs sirt {e_si}");
    }

    #[test]
    fn one_subset_equals_sirt() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let x0 = p.new_vol();
        let os = os_sart(&p, &y, &x0, &OsSartOpts { iterations: 4, subsets: 1, lambda: 0.9, nonneg: true });
        let si = sirt(&p, &y, &x0, &SirtOpts { iterations: 4, lambda: 0.9, nonneg: true, ..Default::default() });
        for i in 0..os.len() {
            assert!((os.data[i] - si.vol.data[i]).abs() < 1e-5, "idx {i}");
        }
    }
}
