//! SIRT — Simultaneous Iterative Reconstruction Technique — on matched
//! operator pairs, with optional non-negativity and view masking.
//!
//! Update: `x ← x + λ · Dv · Aᵀ(Dr · (y − A x))` where `Dr = 1/(A·1)` and
//! `Dv = 1/(Aᵀ·1)` — convergent for `0 < λ < 2` with matched pairs. The
//! view-mask variant implements the paper's data-consistency refinement:
//! only measured views contribute to the residual, so the prior image is
//! pulled toward consistency with the available data while unmeasured
//! directions keep the prior's content.
//!
//! The solver core [`sirt_op`] is generic over any
//! [`crate::ops::LinearOp`] — the planned projector, the stored
//! [`crate::sysmatrix::SystemMatrix`] baseline, or any masked/composed
//! operator; [`sirt`] is the concrete-projector entry point (it plans
//! once and runs the identical core, so its floats are unchanged).

use crate::array::{Sino, Vol3};
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;

/// Options for [`sirt`].
#[derive(Clone, Debug)]
pub struct SirtOpts {
    pub iterations: usize,
    /// Relaxation λ ∈ (0, 2).
    pub lambda: f32,
    /// Clamp negatives after each update (attenuation is non-negative).
    pub nonneg: bool,
    /// Optional per-view weight (1 = measured, 0 = missing). Length must
    /// equal `nviews` when present.
    pub view_mask: Option<Vec<f32>>,
    /// Record ‖residual‖₂ each iteration (for convergence plots).
    pub track_residual: bool,
}

impl Default for SirtOpts {
    fn default() -> Self {
        SirtOpts { iterations: 50, lambda: 1.0, nonneg: true, view_mask: None, track_residual: false }
    }
}

/// Result of a SIRT run.
pub struct SirtResult {
    pub vol: Vol3,
    /// Residual L2 norm per iteration if `track_residual`.
    pub residuals: Vec<f64>,
}

/// Run SIRT from initial volume `x0` (pass zeros for a cold start).
/// Plans the projector once and runs [`sirt_op`] on it: every `A`/`Aᵀ`
/// application in the hot loop reuses the cached per-view geometry,
/// dispatches to the persistent worker pool (no per-iteration spawn
/// wave) and backprojects slab-owned (no `threads × volume` scatter
/// copies, no serial reduction).
pub fn sirt(p: &Projector, y: &Sino, x0: &Vol3, opts: &SirtOpts) -> SirtResult {
    let op = PlanOp::new(p);
    let (x, residuals) = sirt_op(&op, &y.data, &x0.data, opts);
    SirtResult { vol: Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, x), residuals }
}

/// The SIRT core on any matched [`LinearOp`]: returns the solution
/// (domain layout) and the per-iteration residual norms (empty unless
/// `opts.track_residual`). The hot loop allocates nothing.
pub fn sirt_op(op: &dyn LinearOp, y: &[f32], x0: &[f32], opts: &SirtOpts) -> (Vec<f32>, Vec<f64>) {
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    let nviews = op.range_shape().0[0];
    let per_view = if nviews > 0 { rn / nviews } else { 0 };
    assert_eq!(y.len(), rn, "measurement length");
    assert_eq!(x0.len(), dn, "initial volume length");
    let mut x = x0.to_vec();
    // normalizations (mask-aware: missing views contribute nothing)
    let ones_vol = vec![1.0f32; dn];
    let mut row_sum = vec![0.0f32; rn];
    op.apply_into(&ones_vol, &mut row_sum);
    let mut col_ones = vec![1.0f32; rn];
    if let Some(mask) = &opts.view_mask {
        assert_eq!(mask.len(), nviews, "view mask length");
        apply_view_mask_flat(&mut col_ones, mask, per_view);
        apply_view_mask_flat(&mut row_sum, mask, per_view);
    }
    let mut col_sum = vec![0.0f32; dn];
    op.adjoint_into(&col_ones, &mut col_sum);
    let inv_row: Vec<f32> =
        row_sum.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();

    let mut residuals = Vec::new();
    // hoisted work buffers — the hot loop allocates nothing (§Perf)
    let mut ax = vec![0.0f32; rn];
    let mut grad = vec![0.0f32; dn];
    for _ in 0..opts.iterations {
        op.apply_into(&x, &mut ax);
        // r = Dr·(y − Ax), masked
        for i in 0..ax.len() {
            ax[i] = (y[i] - ax[i]) * inv_row[i];
        }
        if let Some(mask) = &opts.view_mask {
            apply_view_mask_flat(&mut ax, mask, per_view);
        }
        if opts.track_residual {
            let n: f64 = ax.iter().map(|&v| (v as f64) * (v as f64)).sum();
            residuals.push(n.sqrt());
        }
        op.adjoint_into(&ax, &mut grad);
        for i in 0..x.len() {
            let mut v = x[i] + opts.lambda * inv_col[i] * grad[i];
            if opts.nonneg && v < 0.0 {
                v = 0.0;
            }
            x[i] = v;
        }
    }
    (x, residuals)
}

/// Multiply every view-block of a flat range buffer by its mask weight
/// (`per_view` = samples per view). One shared definition with the
/// operator layer's [`crate::ops::RowMasked`] — see
/// [`crate::ops::scale_view_blocks`].
pub fn apply_view_mask_flat(data: &mut [f32], mask: &[f32], per_view: usize) {
    crate::ops::scale_view_blocks(data, mask, per_view);
}

/// Multiply every view of `s` by its mask weight.
pub fn apply_view_mask(s: &mut Sino, mask: &[f32]) {
    let n = s.nrows * s.ncols;
    apply_view_mask_flat(&mut s.data, mask, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    fn setup() -> (Projector, Vol3, Sino) {
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(24, 48, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(14.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        (p, truth, y)
    }

    #[test]
    fn converges_toward_truth() {
        let (p, truth, y) = setup();
        let x0 = p.new_vol();
        let r10 = sirt(&p, &y, &x0, &SirtOpts { iterations: 10, ..Default::default() });
        let r60 = sirt(&p, &y, &x0, &SirtOpts { iterations: 60, ..Default::default() });
        let e10 = crate::metrics::rmse(&r10.vol.data, &truth.data);
        let e60 = crate::metrics::rmse(&r60.vol.data, &truth.data);
        assert!(e60 < e10, "rmse should drop: {e10} → {e60}");
        assert!(e60 < 0.004, "rmse {e60}");
    }

    #[test]
    fn residual_monotone_decreasing() {
        let (p, _truth, y) = setup();
        let x0 = p.new_vol();
        let r = sirt(
            &p,
            &y,
            &x0,
            &SirtOpts { iterations: 25, track_residual: true, ..Default::default() },
        );
        for w in r.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "residual rose: {} → {}", w[0], w[1]);
        }
    }

    #[test]
    fn nonneg_enforced() {
        let (p, _truth, y) = setup();
        let x0 = p.new_vol();
        let r = sirt(&p, &y, &x0, &SirtOpts { iterations: 15, ..Default::default() });
        assert!(r.vol.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn masked_views_are_ignored() {
        let (p, _truth, y) = setup();
        // corrupt the masked-out views wildly; result must be unaffected
        let mut y_bad = y.clone();
        let mask: Vec<f32> = (0..y.nviews).map(|v| if v < 8 { 1.0 } else { 0.0 }).collect();
        for view in 8..y.nviews {
            for val in y_bad.view_mut(view) {
                *val = 1e6;
            }
        }
        let opts = SirtOpts { iterations: 10, view_mask: Some(mask), ..Default::default() };
        let x0 = p.new_vol();
        let a = sirt(&p, &y, &x0, &opts);
        let b = sirt(&p, &y_bad, &x0, &opts);
        for i in 0..a.vol.len() {
            assert!((a.vol.data[i] - b.vol.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_start_keeps_prior_in_null_space() {
        let (p, truth, y) = setup();
        // start from truth: a consistent prior should stay (residual ~0)
        let r = sirt(&p, &y, &truth, &SirtOpts { iterations: 5, ..Default::default() });
        let e = crate::metrics::rmse(&r.vol.data, &truth.data);
        assert!(e < 5e-4, "drifted from a consistent prior: {e}");
    }

    #[test]
    fn op_core_runs_against_the_stored_matrix_baseline() {
        // the LinearOp refactor's payoff: the identical solver core
        // drives the sparse-matrix baseline — same geometry, same
        // measurements, near-identical reconstruction
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF).with_threads(1);
        let truth = shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let opts = SirtOpts { iterations: 10, ..Default::default() };
        let via_projector = sirt(&p, &y, &p.new_vol(), &opts).vol;
        let mat = crate::sysmatrix::SystemMatrix::build(&p);
        let x0 = vec![0.0f32; vg.num_voxels()];
        let (via_matrix, _) = sirt_op(&mat, &y.data, &x0, &opts);
        for i in 0..via_matrix.len() {
            assert!(
                (via_projector.data[i] - via_matrix[i]).abs() < 1e-4,
                "idx {i}: projector {} vs matrix {}",
                via_projector.data[i],
                via_matrix[i]
            );
        }
    }
}
