//! SIRT — Simultaneous Iterative Reconstruction Technique — on matched
//! projector pairs, with optional non-negativity and view masking.
//!
//! Update: `x ← x + λ · Dv · Aᵀ(Dr · (y − A x))` where `Dr = 1/(A·1)` and
//! `Dv = 1/(Aᵀ·1)` — convergent for `0 < λ < 2` with matched pairs. The
//! view-mask variant implements the paper's data-consistency refinement:
//! only measured views contribute to the residual, so the prior image is
//! pulled toward consistency with the available data while unmeasured
//! directions keep the prior's content.

use crate::array::{Sino, Vol3};
use crate::projector::Projector;

/// Options for [`sirt`].
#[derive(Clone, Debug)]
pub struct SirtOpts {
    pub iterations: usize,
    /// Relaxation λ ∈ (0, 2).
    pub lambda: f32,
    /// Clamp negatives after each update (attenuation is non-negative).
    pub nonneg: bool,
    /// Optional per-view weight (1 = measured, 0 = missing). Length must
    /// equal `nviews` when present.
    pub view_mask: Option<Vec<f32>>,
    /// Record ‖residual‖₂ each iteration (for convergence plots).
    pub track_residual: bool,
}

impl Default for SirtOpts {
    fn default() -> Self {
        SirtOpts { iterations: 50, lambda: 1.0, nonneg: true, view_mask: None, track_residual: false }
    }
}

/// Result of a SIRT run.
pub struct SirtResult {
    pub vol: Vol3,
    /// Residual L2 norm per iteration if `track_residual`.
    pub residuals: Vec<f64>,
}

/// Run SIRT from initial volume `x0` (pass zeros for a cold start).
/// Plans the projector once; every `A`/`Aᵀ` application in the hot loop
/// reuses the cached per-view geometry, dispatches to the persistent
/// worker pool (no per-iteration spawn wave) and backprojects slab-owned
/// (no `threads × volume` scatter copies, no serial reduction).
pub fn sirt(p: &Projector, y: &Sino, x0: &Vol3, opts: &SirtOpts) -> SirtResult {
    let plan = p.plan();
    let mut x = x0.clone();
    // normalizations (mask-aware: missing views contribute nothing)
    let mut row_sum = plan.forward_ones();
    let mut col_ones = Sino::zeros(y.nviews, y.nrows, y.ncols);
    col_ones.fill(1.0);
    if let Some(mask) = &opts.view_mask {
        assert_eq!(mask.len(), y.nviews, "view mask length");
        apply_view_mask(&mut col_ones, mask);
        apply_view_mask(&mut row_sum, mask);
    }
    let col_sum = plan.back(&col_ones);
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();

    let mut residuals = Vec::new();
    // hoisted work buffers — the hot loop allocates nothing (§Perf)
    let mut ax = p.new_sino();
    let mut grad = p.new_vol();
    for _ in 0..opts.iterations {
        p.forward_with_plan(&plan, &x, &mut ax);
        // r = Dr·(y − Ax), masked
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        if let Some(mask) = &opts.view_mask {
            apply_view_mask(&mut ax, mask);
        }
        if opts.track_residual {
            let n: f64 = ax.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
            residuals.push(n.sqrt());
        }
        p.back_with_plan(&plan, &ax, &mut grad);
        for i in 0..x.len() {
            let mut v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
            if opts.nonneg && v < 0.0 {
                v = 0.0;
            }
            x.data[i] = v;
        }
    }
    SirtResult { vol: x, residuals }
}

/// Multiply every view of `s` by its mask weight.
pub fn apply_view_mask(s: &mut Sino, mask: &[f32]) {
    let n = s.nrows * s.ncols;
    for (view, &m) in mask.iter().enumerate() {
        if m == 1.0 {
            continue;
        }
        for v in &mut s.data[view * n..(view + 1) * n] {
            *v *= m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    fn setup() -> (Projector, Vol3, Sino) {
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(24, 48, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(14.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        (p, truth, y)
    }

    #[test]
    fn converges_toward_truth() {
        let (p, truth, y) = setup();
        let x0 = p.new_vol();
        let r10 = sirt(&p, &y, &x0, &SirtOpts { iterations: 10, ..Default::default() });
        let r60 = sirt(&p, &y, &x0, &SirtOpts { iterations: 60, ..Default::default() });
        let e10 = crate::metrics::rmse(&r10.vol.data, &truth.data);
        let e60 = crate::metrics::rmse(&r60.vol.data, &truth.data);
        assert!(e60 < e10, "rmse should drop: {e10} → {e60}");
        assert!(e60 < 0.004, "rmse {e60}");
    }

    #[test]
    fn residual_monotone_decreasing() {
        let (p, _truth, y) = setup();
        let x0 = p.new_vol();
        let r = sirt(
            &p,
            &y,
            &x0,
            &SirtOpts { iterations: 25, track_residual: true, ..Default::default() },
        );
        for w in r.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "residual rose: {} → {}", w[0], w[1]);
        }
    }

    #[test]
    fn nonneg_enforced() {
        let (p, _truth, y) = setup();
        let x0 = p.new_vol();
        let r = sirt(&p, &y, &x0, &SirtOpts { iterations: 15, ..Default::default() });
        assert!(r.vol.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn masked_views_are_ignored() {
        let (p, _truth, y) = setup();
        // corrupt the masked-out views wildly; result must be unaffected
        let mut y_bad = y.clone();
        let mask: Vec<f32> = (0..y.nviews).map(|v| if v < 8 { 1.0 } else { 0.0 }).collect();
        for view in 8..y.nviews {
            for val in y_bad.view_mut(view) {
                *val = 1e6;
            }
        }
        let opts = SirtOpts { iterations: 10, view_mask: Some(mask), ..Default::default() };
        let x0 = p.new_vol();
        let a = sirt(&p, &y, &x0, &opts);
        let b = sirt(&p, &y_bad, &x0, &opts);
        for i in 0..a.vol.len() {
            assert!((a.vol.data[i] - b.vol.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_start_keeps_prior_in_null_space() {
        let (p, truth, y) = setup();
        // start from truth: a consistent prior should stay (residual ~0)
        let r = sirt(&p, &y, &truth, &SirtOpts { iterations: 5, ..Default::default() });
        let e = crate::metrics::rmse(&r.vol.data, &truth.data);
        assert!(e < 5e-4, "drifted from a consistent prior: {e}");
    }
}
