//! FISTA with a total-variation prox — the model-based regularized
//! reconstruction used for severely ill-posed (limited-angle / few-view)
//! problems, and the "prior model" stand-in of the Figure-3 pipeline.
//!
//! * Lipschitz constant of `∇½‖Ax−y‖² = Aᵀ(Ax−y)` estimated by power
//!   iteration on `AᵀA` (only possible because the pair is matched!).
//! * TV prox solved with FGP (Beck & Teboulle 2009) on each z-slice.
//!
//! The solver core [`fista_tv_op`] and the power iteration
//! [`power_iter_lipschitz_op`] are generic over any
//! [`crate::ops::LinearOp`] — the gradient step is literally
//! [`crate::ops::ProjectionLoss`]'s least-squares gradient, and the
//! power iteration is the normal operator [`crate::ops::Normal`] driven
//! to its top eigenvalue. The concrete-projector entry points plan once
//! and run the identical cores.
//!
//! The power iteration plus the main loop apply `A`/`Aᵀ` hundreds of
//! times; all of them run on the persistent worker pool with slab-owned
//! backprojection, so neither spawns threads nor allocates per-thread
//! volume copies.

use crate::array::{Sino, Vol3};
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;

use super::sirt::apply_view_mask_flat;

/// Isotropic TV of a 2-D slice (for tests/diagnostics).
pub fn tv2d(img: &[f32], nx: usize, ny: usize) -> f64 {
    let mut tv = 0.0f64;
    for y in 0..ny {
        for x in 0..nx {
            let v = img[y * nx + x] as f64;
            let dx = if x + 1 < nx { img[y * nx + x + 1] as f64 - v } else { 0.0 };
            let dy = if y + 1 < ny { img[(y + 1) * nx + x] as f64 - v } else { 0.0 };
            tv += (dx * dx + dy * dy).sqrt();
        }
    }
    tv
}

/// TV-denoise one slice: `argmin_u ½‖u − img‖² + w·TV(u)` via Chambolle's
/// dual projection algorithm (Chambolle 2004), `iters` dual iterations
/// with the standard step `τ = 1/8`.
pub fn tv_prox2d(img: &mut [f32], nx: usize, ny: usize, w: f32, iters: usize) {
    if w <= 0.0 {
        return;
    }
    let n = nx * ny;
    // dual field (px, py); solution is u = img − w·div(p)
    let mut px = vec![0.0f32; n];
    let mut py = vec![0.0f32; n];
    let mut div = vec![0.0f32; n];
    let tau = 0.125f32;
    let inv_w = 1.0 / w;
    for _ in 0..iters {
        // div(p) with the adjoint convention of forward-difference grad
        for y in 0..ny {
            for x in 0..nx {
                let i = y * nx + x;
                let mut d = 0.0;
                if x + 1 < nx {
                    d += px[i];
                }
                if x > 0 {
                    d -= px[i - 1];
                }
                if y + 1 < ny {
                    d += py[i];
                }
                if y > 0 {
                    d -= py[i - nx];
                }
                div[i] = d;
            }
        }
        // p ← (p + τ·∇(div p − img/w)) / (1 + τ·|∇(div p − img/w)|)
        for y in 0..ny {
            for x in 0..nx {
                let i = y * nx + x;
                let c = div[i] - img[i] * inv_w;
                let gx = if x + 1 < nx { (div[i + 1] - img[i + 1] * inv_w) - c } else { 0.0 };
                let gy = if y + 1 < ny { (div[i + nx] - img[i + nx] * inv_w) - c } else { 0.0 };
                let mag = 1.0 + tau * (gx * gx + gy * gy).sqrt();
                px[i] = (px[i] + tau * gx) / mag;
                py[i] = (py[i] + tau * gy) / mag;
            }
        }
    }
    // u = img − w·div(p)
    for y in 0..ny {
        for x in 0..nx {
            let i = y * nx + x;
            let mut d = 0.0;
            if x + 1 < nx {
                d += px[i];
            }
            if x > 0 {
                d -= px[i - 1];
            }
            if y + 1 < ny {
                d += py[i];
            }
            if y > 0 {
                d -= py[i - nx];
            }
            img[i] -= w * d;
        }
    }
}

/// Apply the TV prox slice-by-slice to a flat `[z][y][x]` volume buffer.
pub fn tv_prox_slices(data: &mut [f32], nx: usize, ny: usize, nz: usize, w: f32, iters: usize) {
    let plane = nx * ny;
    for k in 0..nz {
        tv_prox2d(&mut data[k * plane..(k + 1) * plane], nx, ny, w, iters);
    }
}

/// Apply the TV prox slice-by-slice to a volume.
pub fn tv_prox_vol(vol: &mut Vol3, w: f32, iters: usize) {
    let (nx, ny, nz) = (vol.nx, vol.ny, vol.nz);
    tv_prox_slices(&mut vol.data, nx, ny, nz, w, iters);
}

/// Estimate `‖AᵀA‖₂` by power iteration (matched pair required).
pub fn power_iter_lipschitz(p: &Projector, iters: usize, seed: u64) -> f64 {
    power_iter_lipschitz_planned(&p.plan(), iters, seed)
}

/// [`power_iter_lipschitz`] on a prebuilt plan — lets FISTA share one
/// plan between the Lipschitz estimate and the main loop.
pub fn power_iter_lipschitz_planned(
    plan: &crate::projector::ProjectionPlan,
    iters: usize,
    seed: u64,
) -> f64 {
    power_iter_lipschitz_op(plan, iters, seed)
}

/// Power iteration on `AᵀA` for any matched [`LinearOp`] — the largest
/// singular value squared, i.e. the Lipschitz constant of the
/// least-squares gradient.
pub fn power_iter_lipschitz_op(op: &dyn LinearOp, iters: usize, seed: u64) -> f64 {
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut x = vec![0.0f32; dn];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let mut ax = vec![0.0f32; rn];
    let mut atax = vec![0.0f32; dn];
    let mut norm = 1.0f64;
    for _ in 0..iters {
        op.apply_into(&x, &mut ax);
        op.adjoint_into(&ax, &mut atax);
        norm = atax.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        if norm <= 1e-30 {
            return 1.0;
        }
        let inv = (1.0 / norm) as f32;
        for i in 0..x.len() {
            x[i] = atax[i] * inv;
        }
    }
    norm
}

/// Options for [`fista_tv`].
#[derive(Clone, Debug)]
pub struct FistaOpts {
    pub iterations: usize,
    /// TV weight (mm⁻¹ scale of the volume).
    pub tv_weight: f32,
    /// Inner FGP iterations for the prox.
    pub prox_iters: usize,
    pub nonneg: bool,
    /// Optional per-view data mask (limited-angle).
    pub view_mask: Option<Vec<f32>>,
}

impl Default for FistaOpts {
    fn default() -> Self {
        FistaOpts { iterations: 30, tv_weight: 1e-4, prox_iters: 10, nonneg: true, view_mask: None }
    }
}

/// FISTA on `½‖M(Ax − y)‖² + w·TV(x)` from initial `x0`. Plans the
/// projector once; the Lipschitz power iteration and the main loop share
/// the cached per-view geometry.
pub fn fista_tv(p: &Projector, y: &Sino, x0: &Vol3, opts: &FistaOpts) -> Vol3 {
    let op = PlanOp::new(p);
    let x = fista_tv_op(&op, &y.data, &x0.data, opts);
    Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, x)
}

/// The FISTA-TV core on any matched [`LinearOp`]. The TV prox runs on
/// the domain's `[nx, ny, nz]` slices, taken from
/// [`LinearOp::domain_shape`].
pub fn fista_tv_op(op: &dyn LinearOp, y: &[f32], x0: &[f32], opts: &FistaOpts) -> Vec<f32> {
    let d = op.domain_shape().0;
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    let nviews = op.range_shape().0[0];
    let per_view = if nviews > 0 { rn / nviews } else { 0 };
    assert_eq!(y.len(), rn, "measurement length");
    assert_eq!(x0.len(), dn, "initial volume length");
    let lip = power_iter_lipschitz_op(op, 12, 1234).max(1e-12);
    let step = (1.0 / lip) as f32;
    let mut x = x0.to_vec();
    let mut z = x.clone();
    let mut t = 1.0f32;
    let mut ax = vec![0.0f32; rn];
    let mut grad = vec![0.0f32; dn];
    for _ in 0..opts.iterations {
        // gradient at z
        op.apply_into(&z, &mut ax);
        for i in 0..ax.len() {
            ax[i] -= y[i];
        }
        if let Some(mask) = &opts.view_mask {
            apply_view_mask_flat(&mut ax, mask, per_view);
        }
        op.adjoint_into(&ax, &mut grad);
        let mut x_new = z.clone();
        for i in 0..x_new.len() {
            x_new[i] -= step * grad[i];
        }
        tv_prox_slices(&mut x_new, d[0], d[1], d[2], opts.tv_weight * step, opts.prox_iters);
        if opts.nonneg {
            for v in x_new.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        let t_new = (1.0 + (1.0 + 4.0 * t * t).sqrt()) / 2.0;
        let mom = (t - 1.0) / t_new;
        for i in 0..z.len() {
            z[i] = x_new[i] + mom * (x_new[i] - x[i]);
        }
        x = x_new;
        t = t_new;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{angles_deg, Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::{Phantom, Shape};
    use crate::projector::Model;
    use crate::util::rng::Rng;

    #[test]
    fn tv_prox_reduces_tv_keeps_mean() {
        let nx = 24;
        let mut rng = Rng::new(8);
        let mut img = vec![0.0f32; nx * nx];
        for (i, v) in img.iter_mut().enumerate() {
            let x = i % nx;
            *v = if x < nx / 2 { 1.0 } else { 0.0 };
            *v += 0.2 * rng.normal() as f32;
        }
        let tv_before = tv2d(&img, nx, nx);
        let mean_before: f32 = img.iter().sum::<f32>() / img.len() as f32;
        tv_prox2d(&mut img, nx, nx, 0.15, 30);
        let tv_after = tv2d(&img, nx, nx);
        let mean_after: f32 = img.iter().sum::<f32>() / img.len() as f32;
        assert!(tv_after < 0.6 * tv_before, "{tv_before} → {tv_after}");
        assert!((mean_after - mean_before).abs() < 0.01);
    }

    #[test]
    fn lipschitz_positive_and_stable() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
        let p = Projector::new(g, vg, Model::SF);
        let l1 = power_iter_lipschitz(&p, 10, 1);
        let l2 = power_iter_lipschitz(&p, 20, 2);
        assert!(l1 > 0.0);
        assert!((l1 - l2).abs() / l2 < 0.05, "{l1} vs {l2}");
    }

    #[test]
    fn limited_angle_tv_beats_plain_sirt() {
        // piecewise-constant phantom, 60° of 180°: TV regularization
        // should beat unregularized SIRT — the premise of model-based
        // recon for ill-posed CT
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let full = ParallelBeam::standard_2d(40, 36, 1.0);
        let g = ParallelBeam { angles: angles_deg(14, 0.0, 60.0), ..full };
        let geo = Geometry::Parallel(g);
        let p = Projector::new(geo, vg.clone(), Model::SF);
        let ph = Phantom::new(vec![
            Shape::ellipse2d(0.0, 0.0, 9.0, 9.0, 0.0, 0.02),
            Shape::rect2d(2.0, -2.0, 3.0, 3.0, 0.3, 0.015),
        ]);
        let truth = ph.rasterize(&vg, 2);
        let y = p.forward(&truth);
        let x0 = p.new_vol();
        let tv = fista_tv(&p, &y, &x0, &FistaOpts { iterations: 40, tv_weight: 2e-4, ..Default::default() });
        let si = crate::recon::sirt::sirt(&p, &y, &x0, &crate::recon::sirt::SirtOpts { iterations: 40, ..Default::default() });
        let e_tv = crate::metrics::rmse(&tv.data, &truth.data);
        let e_si = crate::metrics::rmse(&si.vol.data, &truth.data);
        assert!(e_tv < e_si, "tv {e_tv} vs sirt {e_si}");
    }
}
