//! Reconstruction algorithms built on the matched projector pairs —
//! the paper's "analytical or iterative reconstruction algorithms"
//! integration claim (§1, last bullet):
//!
//! * [`fbp`] — FBP (parallel), fan FBP, FDK (cone), with the apodized ramp
//!   filters in [`filters`] and the classic *unmatched* pixel-driven
//!   backprojector analytic methods use.
//! * [`sirt`], [`os_sart`], [`cgls`], [`mlem`] — iterative methods on the
//!   matched pair (gradient `Aᵀ(Ax − y)` exactly, per §2.1).
//! * [`fista_tv`] — model-based TV-regularized reconstruction.
//! * [`dc`] — sinogram completion + data-consistency refinement, the §3–4
//!   inference pipeline reproduced by `examples/limited_angle_dc.rs`.
//!
//! These concrete entry points are the kernel layer (they panic on
//! shape misuse); the typed, fallible way to run them is
//! [`crate::api::Scan::solve`] with a [`crate::api::Solver`] selector,
//! which validates every buffer and then runs the identical cores.
//!
//! Every iterative solver is split into a core generic over
//! [`crate::ops::LinearOp`] (`sirt_op`, `os_sart_op`, `cgls_op`,
//! `mlem_op`, `fista_tv_op`, `refine_op`) and a thin concrete-projector
//! entry point that plans once and runs the identical core — so the same
//! solvers drive the on-the-fly projectors, the stored
//! [`crate::sysmatrix::SystemMatrix`] baseline, and any masked/scaled/
//! composed operator, with unchanged floats on the concrete path.

pub mod filters;
pub mod fbp;
pub mod sirt;
pub mod os_sart;
pub mod cgls;
pub mod mlem;
pub mod fista_tv;
pub mod dc;

pub use dc::{
    complete_sinogram, complete_sinogram_op, data_consistency_error, data_consistency_error_op,
    refine, refine_op, DcOpts, ViewMask,
};
pub use fbp::{fbp_fan, fbp_parallel, fdk};
pub use filters::Window;
pub use sirt::{sirt, sirt_op, SirtOpts};
