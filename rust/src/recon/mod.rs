//! Reconstruction algorithms built on the matched projector pairs —
//! the paper's "analytical or iterative reconstruction algorithms"
//! integration claim (§1, last bullet):
//!
//! * [`fbp`] — FBP (parallel), fan FBP, FDK (cone), with the apodized ramp
//!   filters in [`filters`] and the classic *unmatched* pixel-driven
//!   backprojector analytic methods use.
//! * [`sirt`], [`os_sart`], [`cgls`], [`mlem`] — iterative methods on the
//!   matched pair (gradient `Aᵀ(Ax − y)` exactly, per §2.1).
//! * [`fista_tv`] — model-based TV-regularized reconstruction.
//! * [`dc`] — sinogram completion + data-consistency refinement, the §3–4
//!   inference pipeline reproduced by `examples/limited_angle_dc.rs`.

pub mod filters;
pub mod fbp;
pub mod sirt;
pub mod os_sart;
pub mod cgls;
pub mod mlem;
pub mod fista_tv;
pub mod dc;

pub use dc::{complete_sinogram, data_consistency_error, refine, DcOpts, ViewMask};
pub use fbp::{fbp_fan, fbp_parallel, fdk};
pub use filters::Window;
pub use sirt::{sirt, SirtOpts};
