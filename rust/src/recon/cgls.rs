//! CGLS — conjugate gradients on the normal equations `AᵀA x = Aᵀ y`.
//!
//! The textbook example of why the paper insists on *matched* pairs
//! (§2.1: "methods where the exact transpose is used ... stable after over
//! a thousand or more iterations"): CG's convergence theory assumes the
//! operator in the normal equations is exactly `AᵀA`; an unmatched
//! backprojector silently substitutes `BA` with `B ≠ Aᵀ` and diverges.

use crate::array::{Sino, Vol3};
use crate::projector::Projector;
use crate::util::dot_f64;

/// Result of a CGLS run.
pub struct CglsResult {
    pub vol: Vol3,
    /// ‖Aᵀ(y − Ax)‖ per iteration (normal-equation residual).
    pub residuals: Vec<f64>,
}

/// Run `iterations` of CGLS from a zero initial volume.
pub fn cgls(p: &Projector, y: &Sino, iterations: usize) -> CglsResult {
    cgls_from(p, y, &p.new_vol(), iterations)
}

/// Run CGLS from an arbitrary starting volume. Plans the projector once;
/// the CG loop reuses the cached per-view geometry for every `A`/`Aᵀ`.
/// Each application dispatches to the persistent worker pool (no
/// per-iteration thread spawns) and backprojects slab-owned, so solver
/// memory stays at one volume + one sinogram regardless of thread count.
pub fn cgls_from(p: &Projector, y: &Sino, x0: &Vol3, iterations: usize) -> CglsResult {
    let plan = p.plan();
    let mut x = x0.clone();
    // r = y − A x;  s = Aᵀ r;  d = s
    let mut r = y.clone();
    let ax = plan.forward(&x);
    for i in 0..r.len() {
        r.data[i] -= ax.data[i];
    }
    let mut s = plan.back(&r);
    let mut d = s.clone();
    let mut norm_s = dot_f64(&s.data, &s.data);
    let mut residuals = vec![norm_s.sqrt()];

    let mut ad = p.new_sino();
    for _ in 0..iterations {
        if norm_s <= 1e-30 {
            break;
        }
        p.forward_with_plan(&plan, &d, &mut ad);
        let denom = dot_f64(&ad.data, &ad.data);
        if denom <= 1e-30 {
            break;
        }
        let alpha = (norm_s / denom) as f32;
        for i in 0..x.len() {
            x.data[i] += alpha * d.data[i];
        }
        for i in 0..r.len() {
            r.data[i] -= alpha * ad.data[i];
        }
        p.back_with_plan(&plan, &r, &mut s);
        let norm_s_new = dot_f64(&s.data, &s.data);
        let beta = (norm_s_new / norm_s) as f32;
        for i in 0..d.len() {
            d.data[i] = s.data[i] + beta * d.data[i];
        }
        norm_s = norm_s_new;
        residuals.push(norm_s.sqrt());
    }
    CglsResult { vol: x, residuals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    #[test]
    fn solves_consistent_system() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(36, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let r = cgls(&p, &y, 40);
        let e = crate::metrics::rmse(&r.vol.data, &truth.data);
        assert!(e < 2.5e-3, "rmse {e}");
    }

    #[test]
    fn residual_decreases() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Fan(FanBeam::standard(20, 24, 1.2, 60.0, 120.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let r = cgls(&p, &y, 15);
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.2));
    }

    #[test]
    fn warm_start_converges_faster() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(30, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        // prior: slightly perturbed truth
        let mut prior = truth.clone();
        for v in prior.data.iter_mut() {
            *v *= 0.9;
        }
        let cold = cgls(&p, &y, 5);
        let warm = cgls_from(&p, &y, &prior, 5);
        let e_cold = crate::metrics::rmse(&cold.vol.data, &truth.data);
        let e_warm = crate::metrics::rmse(&warm.vol.data, &truth.data);
        assert!(e_warm < e_cold, "warm {e_warm} vs cold {e_cold}");
    }
}
