//! CGLS — conjugate gradients on the normal equations `AᵀA x = Aᵀ y`.
//!
//! The textbook example of why the paper insists on *matched* pairs
//! (§2.1: "methods where the exact transpose is used ... stable after over
//! a thousand or more iterations"): CG's convergence theory assumes the
//! operator in the normal equations is exactly `AᵀA`; an unmatched
//! backprojector silently substitutes `BA` with `B ≠ Aᵀ` and diverges.
//!
//! The solver core [`cgls_op`] is generic over any
//! [`crate::ops::LinearOp`] (planned projector, stored matrix, masked or
//! composed operators); [`cgls`]/[`cgls_from`] are the concrete-projector
//! entry points and run the identical core through a plan built once.

use crate::array::{Sino, Vol3};
use crate::ops::{LinearOp, PlanOp};
use crate::projector::Projector;
use crate::util::dot_f64;

/// Result of a CGLS run.
pub struct CglsResult {
    pub vol: Vol3,
    /// ‖Aᵀ(y − Ax)‖ per iteration (normal-equation residual).
    pub residuals: Vec<f64>,
}

/// Run `iterations` of CGLS from a zero initial volume.
pub fn cgls(p: &Projector, y: &Sino, iterations: usize) -> CglsResult {
    cgls_from(p, y, &p.new_vol(), iterations)
}

/// Run CGLS from an arbitrary starting volume. Plans the projector once;
/// the CG loop reuses the cached per-view geometry for every `A`/`Aᵀ`.
/// Each application dispatches to the persistent worker pool (no
/// per-iteration thread spawns) and backprojects slab-owned, so solver
/// memory stays at one volume + one sinogram regardless of thread count.
pub fn cgls_from(p: &Projector, y: &Sino, x0: &Vol3, iterations: usize) -> CglsResult {
    let op = PlanOp::new(p);
    let (x, residuals) = cgls_op(&op, &y.data, &x0.data, iterations);
    CglsResult { vol: Vol3::from_vec(p.vg.nx, p.vg.ny, p.vg.nz, x), residuals }
}

/// The CGLS core on any matched [`LinearOp`]: returns the solution
/// (domain layout) and the normal-equation residual norm per iteration.
pub fn cgls_op(op: &dyn LinearOp, y: &[f32], x0: &[f32], iterations: usize) -> (Vec<f32>, Vec<f64>) {
    let dn = op.domain_shape().numel();
    let rn = op.range_shape().numel();
    assert_eq!(y.len(), rn, "measurement length");
    assert_eq!(x0.len(), dn, "initial volume length");
    let mut x = x0.to_vec();
    // r = y − A x;  s = Aᵀ r;  d = s
    let mut r = y.to_vec();
    let mut ax = vec![0.0f32; rn];
    op.apply_into(&x, &mut ax);
    for i in 0..r.len() {
        r[i] -= ax[i];
    }
    let mut s = vec![0.0f32; dn];
    op.adjoint_into(&r, &mut s);
    let mut d = s.clone();
    let mut norm_s = dot_f64(&s, &s);
    let mut residuals = vec![norm_s.sqrt()];

    let mut ad = vec![0.0f32; rn];
    for _ in 0..iterations {
        if norm_s <= 1e-30 {
            break;
        }
        op.apply_into(&d, &mut ad);
        let denom = dot_f64(&ad, &ad);
        if denom <= 1e-30 {
            break;
        }
        let alpha = (norm_s / denom) as f32;
        for i in 0..x.len() {
            x[i] += alpha * d[i];
        }
        for i in 0..r.len() {
            r[i] -= alpha * ad[i];
        }
        op.adjoint_into(&r, &mut s);
        let norm_s_new = dot_f64(&s, &s);
        let beta = (norm_s_new / norm_s) as f32;
        for i in 0..d.len() {
            d[i] = s[i] + beta * d[i];
        }
        norm_s = norm_s_new;
        residuals.push(norm_s.sqrt());
    }
    (x, residuals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::phantom::shepp::shepp_logan_2d;
    use crate::projector::Model;

    #[test]
    fn solves_consistent_system() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(36, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let r = cgls(&p, &y, 40);
        let e = crate::metrics::rmse(&r.vol.data, &truth.data);
        assert!(e < 2.5e-3, "rmse {e}");
    }

    #[test]
    fn residual_decreases() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Fan(FanBeam::standard(20, 24, 1.2, 60.0, 120.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        let r = cgls(&p, &y, 15);
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.2));
    }

    #[test]
    fn warm_start_converges_faster() {
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(30, 36, 1.0));
        let p = Projector::new(g, vg.clone(), Model::Joseph);
        let truth = shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
        let y = p.forward(&truth);
        // prior: slightly perturbed truth
        let mut prior = truth.clone();
        for v in prior.data.iter_mut() {
            *v *= 0.9;
        }
        let cold = cgls(&p, &y, 5);
        let warm = cgls_from(&p, &y, &prior, 5);
        let e_cold = crate::metrics::rmse(&cold.vol.data, &truth.data);
        let e_warm = crate::metrics::rmse(&warm.vol.data, &truth.data);
        assert!(e_warm < e_cold, "warm {e_warm} vs cold {e_cold}");
    }
}
