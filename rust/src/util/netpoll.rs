//! Readiness polling for the async serving plane — `poll(2)` without a
//! dependency.
//!
//! The event-loop server ([`crate::coordinator::server`]) multiplexes
//! hundreds of nonblocking sockets on one OS thread, which needs exactly
//! one kernel facility: "which of these fds are readable/writable?".
//! `std` does not expose `poll`/`epoll`, and the crate policy is to stay
//! dependency-light (no `tokio`, no `libc` — mirroring how
//! [`crate::util::pool`] hand-rolls its worker pool), so this module
//! makes the one syscall directly via inline assembly on the platforms
//! we serve from (Linux x86_64 / aarch64), with a portable fallback
//! everywhere else.
//!
//! ## Fallback and self-healing semantics
//!
//! On non-Linux targets — and whenever the syscall reports an error —
//! [`poll_fds`] sleeps a few milliseconds and then marks **every** fd
//! ready for whatever events it asked for. That is safe, not just
//! convenient, because the serving loop's contract is that all sockets
//! are nonblocking and every readiness signal is treated as a *hint*: a
//! spurious "readable" costs one `EWOULDBLOCK` read and the connection
//! state machine is untouched. The fallback degrades the event loop to a
//! small-sleep busy poll (higher idle CPU, same behavior); it can never
//! hang it or desync a stream.

use std::time::Duration;

/// Readable-data event bit (POSIX `POLLIN`).
pub const POLLIN: i16 = 0x001;
/// Writable-space event bit (POSIX `POLLOUT`).
pub const POLLOUT: i16 = 0x004;
/// Error condition (POSIX `POLLERR`; output-only, always polled).
pub const POLLERR: i16 = 0x008;
/// Peer hangup (POSIX `POLLHUP`; output-only, always polled).
pub const POLLHUP: i16 = 0x010;

/// One entry of a `poll(2)` set — layout-compatible with the kernel's
/// `struct pollfd` (fd, requested events, returned events).
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// The fd has data to read (or a hangup/error to observe via read).
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLERR | POLLHUP) != 0
    }

    /// The fd has buffer space to write into.
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR) != 0
    }

    /// The peer hung up or the fd errored.
    pub fn hangup(&self) -> bool {
        self.revents & (POLLERR | POLLHUP) != 0
    }
}

/// Wait up to `timeout` for readiness on `fds`, filling each entry's
/// `revents`. Returns the number of ready entries (0 on timeout). Never
/// fails: syscall errors and unsupported platforms degrade to the
/// sleep-and-mark-all-ready fallback described in the module docs.
pub fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> usize {
    for f in fds.iter_mut() {
        f.revents = 0;
    }
    if fds.is_empty() {
        std::thread::sleep(timeout);
        return 0;
    }
    let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as i32;
    match sys_poll(fds, timeout_ms) {
        Some(n) => n,
        None => {
            // degraded mode: brief sleep, then optimistically report every
            // requested event — safe against nonblocking fds (see module
            // docs), and self-healing: the next tick retries the syscall
            std::thread::sleep(timeout.min(Duration::from_millis(5)));
            for f in fds.iter_mut() {
                f.revents = f.events;
            }
            fds.len()
        }
    }
}

/// `poll(2)` on Linux x86_64: syscall 7, args (fds ptr, nfds, timeout_ms).
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sys_poll(fds: &mut [PollFd], timeout_ms: i32) -> Option<usize> {
    let ret: isize;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 7isize => ret,
            in("rdi") fds.as_mut_ptr(),
            in("rsi") fds.len(),
            in("rdx") timeout_ms as isize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    // EINTR is a normal wakeup (signal during sleep): report "nothing
    // ready" and let the caller's next tick poll again
    if ret == -4 {
        return Some(0);
    }
    if ret < 0 {
        return None;
    }
    Some(ret as usize)
}

/// `ppoll(2)` on Linux aarch64 (which has no plain `poll` syscall):
/// syscall 73, args (fds ptr, nfds, timespec, sigmask = null, size).
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sys_poll(fds: &mut [PollFd], timeout_ms: i32) -> Option<usize> {
    #[repr(C)]
    struct Timespec {
        sec: i64,
        nsec: i64,
    }
    let ts = Timespec {
        sec: (timeout_ms / 1000) as i64,
        nsec: (timeout_ms % 1000) as i64 * 1_000_000,
    };
    let ret: isize;
    unsafe {
        std::arch::asm!(
            "svc #0",
            in("x8") 73isize,
            inlateout("x0") fds.as_mut_ptr() as isize => ret,
            in("x1") fds.len(),
            in("x2") &ts as *const Timespec,
            in("x3") 0usize, // no signal mask (x4 sigsetsize then unused)
            in("x4") 0usize,
            options(nostack),
        );
    }
    if ret == -4 {
        return Some(0); // EINTR
    }
    if ret < 0 {
        return None;
    }
    Some(ret as usize)
}

/// Unsupported platform: always take the fallback path.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sys_poll(_fds: &mut [PollFd], _timeout_ms: i32) -> Option<usize> {
    None
}

/// Raw fd of a socket-like object, for [`poll_fds`] registration. On
/// non-unix targets there is no fd to extract; -1 keeps the entry inert
/// (the kernel ignores negative fds in a poll set, and the fallback path
/// marks it ready, which nonblocking I/O tolerates).
#[cfg(unix)]
pub fn raw_fd<T: std::os::unix::io::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(not(unix))]
pub fn raw_fd<T>(_t: &T) -> i32 {
    -1
}

/// Cross-thread wakeup for a [`poll_fds`] loop — the self-pipe trick
/// with std-only types.
///
/// A poll-based event loop that also waits on out-of-band completions
/// (worker threads finishing jobs) must either tick on a short timeout
/// (burning idle CPU) or own an fd those threads can make readable.
/// `std` exposes no `pipe(2)`/`eventfd(2)`, so the waker is a UDP
/// socket bound to the loopback and connected to itself: [`Waker::wake`]
/// sends a one-byte datagram to the socket's own address, which makes
/// the fd poll readable until [`Waker::drain`] consumes it. Datagrams
/// never merge or split, the loopback never drops under the socket
/// buffer size, and a full buffer means wakeups are already pending —
/// so `wake` treats every send error as "a wakeup is latched or the
/// waker is degraded" and the loop's idle-tick timeout remains the
/// safety net either way.
pub struct Waker {
    sock: std::net::UdpSocket,
}

impl Waker {
    pub fn new() -> std::io::Result<Waker> {
        let sock = std::net::UdpSocket::bind("127.0.0.1:0")?;
        sock.connect(sock.local_addr()?)?;
        sock.set_nonblocking(true)?;
        Ok(Waker { sock })
    }

    /// Make the owning loop's current (or next) [`poll_fds`] call
    /// return promptly. Callable from any thread; never blocks.
    /// `WouldBlock` (socket buffer full of unread wakeups) is success:
    /// the fd is already readable.
    pub fn wake(&self) {
        let _ = self.sock.send(&[1u8]);
    }

    /// Consume every pending wakeup datagram so the fd stops polling
    /// readable — call once per loop tick when the waker's poll entry
    /// reports readable. A wake racing in *during* the drain leaves its
    /// datagram for the next tick, so no wakeup is ever lost.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        while self.sock.recv(&mut buf).is_ok() {}
    }

    /// The fd to register with [`POLLIN`] in the poll set.
    pub fn fd(&self) -> i32 {
        raw_fd(&self.sock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn empty_set_times_out_without_spinning() {
        let t0 = std::time::Instant::now();
        let n = poll_fds(&mut [], Duration::from_millis(20));
        assert_eq!(n, 0);
        assert!(t0.elapsed() >= Duration::from_millis(10), "timeout honored");
    }

    #[test]
    fn listener_becomes_readable_on_pending_accept() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut fds = [PollFd::new(raw_fd(&listener), POLLIN)];
        // nothing pending: not readable within a short timeout (on real
        // poll; the fallback may spuriously report ready, which the
        // contract allows — so only assert the positive direction below)
        let _ = poll_fds(&mut fds, Duration::from_millis(1));
        let _client = TcpStream::connect(addr).unwrap();
        // pending connection: must become readable promptly
        let mut ready = false;
        for _ in 0..100 {
            if poll_fds(&mut fds, Duration::from_millis(20)) > 0 && fds[0].readable() {
                ready = true;
                break;
            }
        }
        assert!(ready, "listener with a pending accept must poll readable");
    }

    #[test]
    fn stream_readability_follows_data() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let mut fds = [PollFd::new(raw_fd(&server_side), POLLIN | POLLOUT)];
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut readable = false;
        for _ in 0..100 {
            if poll_fds(&mut fds, Duration::from_millis(20)) > 0 && fds[0].readable() {
                readable = true;
                break;
            }
        }
        assert!(readable, "bytes in flight must poll readable");
        // a fresh connected socket has send-buffer space
        assert!(fds[0].writable() || {
            poll_fds(&mut fds, Duration::from_millis(20));
            fds[0].writable()
        });
    }

    #[test]
    fn waker_latches_readable_until_drained() {
        let waker = Waker::new().unwrap();
        waker.wake();
        waker.wake(); // coalesced wakes are fine
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        let mut ready = false;
        for _ in 0..100 {
            if poll_fds(&mut fds, Duration::from_millis(20)) > 0 && fds[0].readable() {
                ready = true;
                break;
            }
        }
        assert!(ready, "a woken waker must poll readable");
        waker.drain();
        // drained: recv would block again (no assertion on the poll —
        // the degraded fallback may spuriously report readable)
        let mut buf = [0u8; 8];
        assert!(waker.sock.recv(&mut buf).is_err(), "drain must consume every datagram");
    }

    #[test]
    fn wake_from_another_thread_unblocks_a_long_poll() {
        use std::sync::Arc;
        let waker = Arc::new(Waker::new().unwrap());
        let w2 = waker.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w2.wake();
        });
        let t0 = std::time::Instant::now();
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        // a 2-second poll must return well before its timeout
        poll_fds(&mut fds, Duration::from_secs(2));
        assert!(t0.elapsed() < Duration::from_secs(1), "wake() must interrupt the poll");
        t.join().unwrap();
        waker.drain();
    }

    #[test]
    fn wake_burst_coalesces_into_one_drain() {
        // the shard channel wakes the event loop once per submitted task;
        // a burst of submissions must cost one drain, not one syscall
        // round-trip per wake, and must not leave a stale readable fd
        let waker = Waker::new().unwrap();
        for _ in 0..16 {
            waker.wake();
        }
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        let mut ready = false;
        for _ in 0..100 {
            if poll_fds(&mut fds, Duration::from_millis(20)) > 0 && fds[0].readable() {
                ready = true;
                break;
            }
        }
        assert!(ready, "a burst-woken waker must poll readable");
        waker.drain();
        let mut buf = [0u8; 8];
        assert!(
            waker.sock.recv(&mut buf).is_err(),
            "one drain must consume the whole burst"
        );
        // the waker still works after the burst: a fresh wake re-latches
        waker.wake();
        let mut ready_again = false;
        for _ in 0..100 {
            if poll_fds(&mut fds, Duration::from_millis(20)) > 0 && fds[0].readable() {
                ready_again = true;
                break;
            }
        }
        assert!(ready_again, "a drained waker must latch again on the next wake");
        waker.drain();
    }

    #[test]
    fn dead_peer_degrades_to_the_safety_net_tick() {
        // if the waker's loopback peer somehow dies (the documented
        // degraded mode), wake() must stay non-blocking and never panic:
        // the owning loop falls back to its idle-tick timeout. Re-point
        // the socket at a freshly-freed port to simulate the dead peer.
        let waker = Waker::new().unwrap();
        let dead_addr = {
            let victim = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
            victim.local_addr().unwrap()
        }; // victim dropped: nothing listens there any more
        waker.sock.connect(dead_addr).unwrap();
        let t0 = std::time::Instant::now();
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        for _ in 0..8 {
            waker.wake(); // may land ICMP-refused errors on the socket; must not panic
            poll_fds(&mut fds, Duration::from_millis(25));
            if fds[0].readable() {
                waker.drain(); // drain must also swallow queued socket errors
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "a degraded waker must cost at most the safety-net tick per iteration"
        );
    }
}
