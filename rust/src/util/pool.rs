//! Persistent data-parallel worker pool (rayon replacement).
//!
//! The projector drivers parallelize over views (forward) or voxel slabs
//! (backprojection), and iterative solvers apply them thousands of times
//! per solve. Spawning OS threads per operator application (the original
//! `std::thread::scope` helpers) put a spawn/join wave on every `A`/`Aᵀ`;
//! this module instead keeps one process-wide pool of parked workers
//! (sized by `LEAP_THREADS`, else the available parallelism) that every
//! parallel region is dispatched to:
//!
//! * [`run_region`] — the primitive: `nslots` logical workers each run
//!   `body(slot)` exactly once. The caller participates (it claims slots
//!   too), so a region always makes progress even when every pool worker
//!   is busy — which also makes nested regions deadlock-free.
//! * [`parallel_chunks`] — contiguous index chunks, one per slot (static
//!   schedule; deterministic chunk layout for a given worker count).
//! * [`parallel_items`] — dynamic schedule: an atomic cursor hands out
//!   single items, so irregular per-item costs (e.g. cone-beam SF views
//!   with very different footprint sizes) load-balance automatically.
//!   Safe whenever each item owns its output; the item→output mapping is
//!   fixed, so results never depend on which worker ran an item.
//! * [`parallel_map_reduce`] — per-chunk partial results combined by an
//!   order-preserving parallel tree reduction (adjacent blocks merge
//!   left-to-right), deterministic for associative-but-not-commutative
//!   reducers and exact for integer-valued sums.
//!
//! Worker panics are caught, the first payload is stored, and
//! [`run_region`] re-raises it on the calling thread after the region
//! drains — a panicking closure can never wedge or poison the pool.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Parse a `LEAP_THREADS`-style value. `Some(n.max(1))` when the string is
/// a valid count (`"0"` means "auto-pick at least one" and clamps to 1),
/// `None` for garbage — the caller then falls back to the hardware count.
pub fn threads_from_env(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|v| v.trim().parse::<usize>().ok()).map(|n| n.max(1))
}

/// Number of workers to use: `LEAP_THREADS` env var, else available
/// parallelism, else 1.
pub fn default_threads() -> usize {
    threads_from_env(std::env::var("LEAP_THREADS").ok().as_deref()).unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

/// One parallel region: `nslots` logical workers over a type-erased body.
/// The body reference is only dereferenced between a successful slot claim
/// and the matching `finished` increment, and [`run_region`] does not
/// return before `finished == nslots` — so the erased borrow can never
/// outlive the caller's stack frame.
struct Region {
    body: RegionBody,
    nslots: usize,
    next_slot: AtomicUsize,
    done: Mutex<RegionDone>,
    all_done: Condvar,
}

struct RegionDone {
    finished: usize,
    panic: Option<Box<dyn Any + Send>>,
}

/// Lifetime-erased `&(dyn Fn(usize) + Sync)`. Safety argument lives on
/// [`Region`].
struct RegionBody(*const (dyn Fn(usize) + Sync));
unsafe impl Send for RegionBody {}
unsafe impl Sync for RegionBody {}

impl Region {
    fn exhausted(&self) -> bool {
        self.next_slot.load(Ordering::Relaxed) >= self.nslots
    }

    /// Claim the next unclaimed slot, if any. Each slot is handed out
    /// exactly once across all participating threads.
    fn claim(&self) -> Option<usize> {
        if self.exhausted() {
            return None;
        }
        let s = self.next_slot.fetch_add(1, Ordering::Relaxed);
        (s < self.nslots).then_some(s)
    }

    fn run_slot(&self, slot: usize) {
        // SAFETY: see the Region doc comment — the caller of run_region is
        // still blocked in wait_done() while any claimed slot runs.
        let body = unsafe { &*self.body.0 };
        let result = catch_unwind(AssertUnwindSafe(|| body(slot)));
        let mut d = self.done.lock().unwrap();
        d.finished += 1;
        if let Err(payload) = result {
            if d.panic.is_none() {
                d.panic = Some(payload);
            }
        }
        if d.finished == self.nslots {
            self.all_done.notify_all();
        }
    }

    /// Block until every slot has finished; re-raise the first panic.
    fn wait_done(&self) {
        let mut d = self.done.lock().unwrap();
        while d.finished < self.nslots {
            d = self.all_done.wait(d).unwrap();
        }
        if let Some(payload) = d.panic.take() {
            drop(d);
            std::panic::resume_unwind(payload);
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Region>>>,
    available: Condvar,
    /// Pool worker threads (excluding callers, which always participate).
    workers: usize,
    /// Regions dispatched to the pool since process start (telemetry).
    regions: AtomicU64,
}

static POOL: OnceLock<Arc<PoolShared>> = OnceLock::new();

/// The process-wide pool, spawning its workers on first use. Sized once
/// from [`default_threads`] (`LEAP_THREADS` is read at first dispatch);
/// per-call `workers` arguments above the pool size are multiplexed over
/// the available threads without changing results.
fn pool() -> &'static Arc<PoolShared> {
    POOL.get_or_init(|| {
        let workers = default_threads().saturating_sub(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            workers,
            regions: AtomicU64::new(0),
        });
        for i in 0..workers {
            let s = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("leap-pool-{i}"))
                .spawn(move || worker_loop(&s))
                .expect("failed to spawn pool worker");
        }
        shared
    })
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let region: Arc<Region> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // drop fully-claimed regions; their remaining work is
                // finishing on the threads that claimed it
                while q.front().is_some_and(|r| r.exhausted()) {
                    q.pop_front();
                }
                if let Some(r) = q.front() {
                    break Arc::clone(r);
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        while let Some(slot) = region.claim() {
            region.run_slot(slot);
        }
    }
}

/// Pool telemetry: `(worker_threads, regions_dispatched)`. Does not force
/// pool start-up; before first use it reports the configured size.
pub fn pool_stats() -> (usize, u64) {
    match POOL.get() {
        Some(p) => (p.workers, p.regions.load(Ordering::Relaxed)),
        None => (default_threads().saturating_sub(1), 0),
    }
}

/// Run `body(slot)` once for each `slot in 0..nslots`, in parallel on the
/// persistent pool. The calling thread participates, claiming slots until
/// none remain, then blocks until slots claimed by pool workers finish.
/// Panics in any slot propagate to the caller (first payload wins) after
/// the whole region has drained.
pub fn run_region<F>(nslots: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    match nslots {
        0 => return,
        1 => {
            body(0);
            return;
        }
        _ => {}
    }
    let body_dyn: &(dyn Fn(usize) + Sync) = &body;
    let region = Arc::new(Region {
        body: RegionBody(body_dyn as *const (dyn Fn(usize) + Sync)),
        nslots,
        next_slot: AtomicUsize::new(0),
        done: Mutex::new(RegionDone { finished: 0, panic: None }),
        all_done: Condvar::new(),
    });
    let shared = pool();
    if shared.workers > 0 {
        shared.regions.fetch_add(1, Ordering::Relaxed);
        shared.queue.lock().unwrap().push_back(Arc::clone(&region));
        shared.available.notify_all();
    }
    while let Some(slot) = region.claim() {
        region.run_slot(slot);
    }
    region.wait_done();
}

// ---------------------------------------------------------------------------
// schedules built on run_region
// ---------------------------------------------------------------------------

/// Split `n` items into at most `workers` contiguous `(start, end)` chunks.
pub fn chunk_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return vec![];
    }
    let workers = workers.max(1).min(n);
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(start, end)` over contiguous chunks of `0..n` in parallel
/// (static schedule: the chunk layout depends only on `n` and `workers`).
pub fn parallel_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let ranges = chunk_ranges(n, workers);
    if ranges.len() <= 1 {
        if let Some(&(s, e)) = ranges.first() {
            f(s, e);
        }
        return;
    }
    run_region(ranges.len(), |slot| {
        let (s, e) = ranges[slot];
        f(s, e);
    });
}

/// Run `f(item)` for every item of `0..n` with dynamic scheduling: an
/// atomic cursor hands items to whichever worker is free next, so wildly
/// uneven per-item costs still load-balance. Every item is executed
/// exactly once; which thread runs it is unspecified, so `f` must own its
/// output per item (as the per-view / per-slab projector loops do).
pub fn parallel_items<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_items_with(n, workers, || (), |(), i| f(i));
}

/// [`parallel_items`] with per-worker scratch state: each participating
/// worker builds one `init()` value and threads it through every item it
/// claims — the pattern for reusable per-worker buffers (e.g. the cone
/// projector's on-the-fly footprint scratch) without per-item allocation
/// churn. Scheduling must not affect results, so `f` may use the scratch
/// only as a cache/buffer, never to carry cross-item values.
pub fn parallel_items_with<S, I, F>(n: usize, workers: usize, init: I, f: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        let mut scratch = init();
        for i in 0..n {
            f(&mut scratch, i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    run_region(workers, |_slot| {
        let mut scratch = init();
        loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(&mut scratch, i);
        }
    });
}

/// Run `f(start, end) -> T` over chunks of `0..n` and combine the partial
/// results with `reduce` via an order-preserving parallel tree reduction:
/// adjacent blocks merge left-to-right (`(p0⊕p1)⊕(p2⊕p3)…`), so the
/// result is deterministic for associative-but-not-commutative reducers
/// and identical for any pool size at a fixed `workers` count.
pub fn parallel_map_reduce<T, F, R>(n: usize, workers: usize, f: F, reduce: R) -> Option<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T + Sync,
{
    let ranges = chunk_ranges(n, workers);
    if ranges.is_empty() {
        return None;
    }
    if ranges.len() == 1 {
        let (s, e) = ranges[0];
        return Some(f(s, e));
    }
    let cells: Vec<Mutex<Option<T>>> = (0..ranges.len()).map(|_| Mutex::new(None)).collect();
    run_region(ranges.len(), |slot| {
        let (s, e) = ranges[slot];
        *cells[slot].lock().unwrap() = Some(f(s, e));
    });
    // tree rounds: at stride d, cell i absorbs cell i+d for i ≡ 0 (mod 2d).
    // Disjoint pairs per round, so the merges themselves run in parallel.
    let len = cells.len();
    let mut stride = 1;
    while stride < len {
        let pairs: Vec<usize> =
            (0..len).step_by(2 * stride).filter(|i| i + stride < len).collect();
        let merge = |i: usize| {
            let b = cells[i + stride].lock().unwrap().take();
            let mut left = cells[i].lock().unwrap();
            let a = left.take();
            *left = match (a, b) {
                (Some(a), Some(b)) => Some(reduce(a, b)),
                (a, None) => a,
                (None, b) => b,
            };
        };
        if pairs.len() >= 2 {
            parallel_items(pairs.len(), pairs.len(), |p| merge(pairs[p]));
        } else {
            pairs.into_iter().for_each(merge);
        }
        stride *= 2;
    }
    cells.into_iter().next().and_then(|c| c.into_inner().unwrap())
}

/// Shared-by-workers writer over an `f32` buffer for disjoint parallel
/// writes (forward projection: each worker owns its view / detector-row
/// slab of the sinogram; slab-owned backprojection and FBP: each worker
/// owns its voxel rows of the volume). All writes go through the raw
/// pointer, so no two overlapping `&mut` references are ever
/// materialized — the workers' disjoint index ownership is the entire
/// aliasing contract.
pub struct ParWriter(*mut f32);
unsafe impl Send for ParWriter {}
unsafe impl Sync for ParWriter {}
impl ParWriter {
    pub fn new(buf: &mut [f32]) -> ParWriter {
        ParWriter(buf.as_mut_ptr())
    }

    /// `buf[idx] += v`. Caller contract: `idx` is in bounds and owned by
    /// exactly this worker for the duration of the parallel region.
    #[inline]
    pub fn add(&self, idx: usize, v: f32) {
        unsafe { *self.0.add(idx) += v }
    }

    /// `buf[idx] = v`. Same contract as [`Self::add`].
    #[inline]
    pub fn set(&self, idx: usize, v: f32) {
        unsafe { *self.0.add(idx) = v }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for n in [0usize, 1, 2, 7, 100, 101] {
            for w in [1usize, 2, 3, 8, 200] {
                let r = chunk_ranges(n, w);
                let total: usize = r.iter().map(|&(s, e)| e - s).sum();
                assert_eq!(total, n, "n={n} w={w}");
                // contiguous, ordered, non-empty
                let mut prev = 0;
                for &(s, e) in &r {
                    assert_eq!(s, prev);
                    assert!(e > s);
                    prev = e;
                }
            }
        }
    }

    #[test]
    fn threads_env_parsing() {
        assert_eq!(threads_from_env(Some("8")), Some(8));
        assert_eq!(threads_from_env(Some(" 3 ")), Some(3));
        // "0" clamps to 1 rather than disabling parallel execution
        assert_eq!(threads_from_env(Some("0")), Some(1));
        // garbage falls through to the hardware count
        assert_eq!(threads_from_env(Some("lots")), None);
        assert_eq!(threads_from_env(Some("-4")), None);
        assert_eq!(threads_from_env(Some("3.5")), None);
        assert_eq!(threads_from_env(Some("")), None);
        assert_eq!(threads_from_env(None), None);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn parallel_chunks_visits_all() {
        let count = AtomicUsize::new(0);
        parallel_chunks(1000, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn items_execute_exactly_once_under_contention() {
        // dynamic-scheduler completeness: many small items, more logical
        // workers than cores — every item must run exactly once
        let n = 10_000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(n, 16, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn items_with_scratch_is_per_worker() {
        // every item runs exactly once; scratch is built at most once per
        // logical worker, not per item
        let inits = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        parallel_items_with(
            100,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::with_capacity(8)
            },
            |scratch, i| {
                scratch.push(i);
                done.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(done.load(Ordering::Relaxed), 100);
        let n = inits.load(Ordering::Relaxed);
        assert!((1..=4).contains(&n), "scratch inits {n}");
    }

    #[test]
    fn items_empty_and_single() {
        parallel_items(0, 4, |_| panic!("must not run"));
        let count = AtomicUsize::new(0);
        parallel_items(1, 4, |i| {
            assert_eq!(i, 0);
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_reduce_sums() {
        let total =
            parallel_map_reduce(100, 7, |s, e| (s..e).sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(total, (0..100).sum::<usize>());
    }

    #[test]
    fn map_reduce_empty() {
        assert_eq!(parallel_map_reduce(0, 4, |_, _| 1usize, |a, b| a + b), None);
    }

    #[test]
    fn map_reduce_order_deterministic() {
        // Concatenation is associative but not commutative: the tree
        // reduction must merge adjacent blocks left-to-right regardless of
        // which worker finishes first.
        let s = parallel_map_reduce(
            26,
            5,
            |s, e| (s..e).map(|i| (b'a' + i as u8) as char).collect::<String>(),
            |a, b| a + &b,
        )
        .unwrap();
        assert_eq!(s, "abcdefghijklmnopqrstuvwxyz");
    }

    #[test]
    fn map_reduce_exact_sums_bit_identical_1_vs_n_workers() {
        // integer-valued f32 partials stay exact (well under 2^24), so the
        // chunked tree-reduced total must be bit-identical to the
        // single-worker fold for every worker count
        let f = |s: usize, e: usize| (s..e).map(|i| (i % 7) as f32).sum::<f32>();
        let serial = parallel_map_reduce(10_000, 1, f, |a, b| a + b).unwrap();
        for w in [2usize, 3, 5, 8, 16, 33] {
            let par = parallel_map_reduce(10_000, w, f, |a, b| a + b).unwrap();
            assert_eq!(par.to_bits(), serial.to_bits(), "workers {w}");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let hit = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_chunks(100, 4, |s, _e| {
                hit.fetch_add(1, Ordering::Relaxed);
                if s >= 50 {
                    panic!("intentional test panic");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate out of the region");
        // the pool must stay fully operational afterwards
        let count = AtomicUsize::new(0);
        parallel_chunks(1000, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
        let total = parallel_map_reduce(64, 8, |s, e| e - s, |a, b| a + b).unwrap();
        assert_eq!(total, 64);
    }

    #[test]
    fn nested_regions_complete() {
        // a region body opening its own region must not deadlock: callers
        // always self-claim slots, so progress never depends on free pool
        // workers
        let total = AtomicUsize::new(0);
        parallel_chunks(4, 4, |s, e| {
            for _ in s..e {
                parallel_items(10, 2, |_| {
                    total.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn region_slots_each_run_once() {
        let n = 37;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        run_region(n, |slot| {
            counts[slot].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_stats_reports() {
        // force the pool up, then check the counters move
        let (_, before) = pool_stats();
        parallel_chunks(100, 4, |_, _| {});
        let (workers, after) = pool_stats();
        // on a 1-core box the pool legitimately has 0 workers and regions
        // run inline; only assert monotonicity in that case
        if workers > 0 {
            assert!(after > before, "region dispatch must be counted");
        }
    }

}
