//! Scoped data-parallel helpers over `std::thread` (rayon replacement).
//!
//! The projector drivers parallelize over views (forward) or voxel slabs
//! (backprojection). `parallel_chunks` splits an index range into
//! contiguous chunks, one per worker, and runs the closure in scoped
//! threads; `parallel_map_reduce` additionally collects per-worker partial
//! results (used for per-thread accumulation volumes in scatter-style
//! backprojection, which keeps the pair *exactly* matched without atomics).

/// Number of workers to use: `LEAP_THREADS` env var, else available
/// parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LEAP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `n` items into at most `workers` contiguous `(start, end)` chunks.
pub fn chunk_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return vec![];
    }
    let workers = workers.max(1).min(n);
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(start, end)` over contiguous chunks of `0..n` in parallel.
pub fn parallel_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let ranges = chunk_ranges(n, workers);
    if ranges.len() <= 1 {
        if let Some(&(s, e)) = ranges.first() {
            f(s, e);
        }
        return;
    }
    std::thread::scope(|scope| {
        for &(s, e) in &ranges {
            let f = &f;
            scope.spawn(move || f(s, e));
        }
    });
}

/// Run `f(start, end) -> T` over chunks of `0..n` and reduce the partial
/// results with `reduce`. Chunks are reduced in index order, so the result
/// is deterministic for associative-but-not-commutative reducers too.
pub fn parallel_map_reduce<T, F, R>(n: usize, workers: usize, f: F, reduce: R) -> Option<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    let ranges = chunk_ranges(n, workers);
    if ranges.is_empty() {
        return None;
    }
    if ranges.len() == 1 {
        let (s, e) = ranges[0];
        return Some(f(s, e));
    }
    let mut parts: Vec<Option<T>> = Vec::new();
    parts.resize_with(ranges.len(), || None);
    std::thread::scope(|scope| {
        for (slot, &(s, e)) in parts.iter_mut().zip(ranges.iter()) {
            let f = &f;
            scope.spawn(move || {
                *slot = Some(f(s, e));
            });
        }
    });
    let mut it = parts.into_iter().map(|p| p.expect("worker panicked"));
    let first = it.next()?;
    Some(it.fold(first, reduce))
}

/// Element-wise `dst += src` (the reduction step for per-thread volumes).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for n in [0usize, 1, 2, 7, 100, 101] {
            for w in [1usize, 2, 3, 8, 200] {
                let r = chunk_ranges(n, w);
                let total: usize = r.iter().map(|&(s, e)| e - s).sum();
                assert_eq!(total, n, "n={n} w={w}");
                // contiguous, ordered, non-empty
                let mut prev = 0;
                for &(s, e) in &r {
                    assert_eq!(s, prev);
                    assert!(e > s);
                    prev = e;
                }
            }
        }
    }

    #[test]
    fn parallel_chunks_visits_all() {
        let count = AtomicUsize::new(0);
        parallel_chunks(1000, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn map_reduce_sums() {
        let total =
            parallel_map_reduce(100, 7, |s, e| (s..e).sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(total, (0..100).sum::<usize>());
    }

    #[test]
    fn map_reduce_empty() {
        assert_eq!(parallel_map_reduce(0, 4, |_, _| 1usize, |a, b| a + b), None);
    }

    #[test]
    fn map_reduce_order_deterministic() {
        // Concatenation is associative but not commutative: chunk order must
        // be preserved regardless of which worker finishes first.
        let s = parallel_map_reduce(
            26,
            5,
            |s, e| (s..e).map(|i| (b'a' + i as u8) as char).collect::<String>(),
            |a, b| a + &b,
        )
        .unwrap();
        assert_eq!(s, "abcdefghijklmnopqrstuvwxyz");
    }

    #[test]
    fn add_assign_works() {
        let mut a = vec![1.0f32; 4];
        add_assign(&mut a, &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a, vec![3.0, 4.0, 5.0, 6.0]);
    }
}
