//! Deterministic PRNG (splitmix64 seeding + xoshiro256**).
//!
//! Used by phantom generation, workload generation and the property-test
//! helpers. Deterministic across platforms so every experiment in
//! EXPERIMENTS.md is exactly reproducible from its seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform values in `[lo, hi)` — used to make random
    /// test volumes/sinograms for adjoint identities.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.range(lo, hi) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut mean = 0.0;
        let mut var = 0.0;
        for _ in 0..n {
            let x = r.normal();
            mean += x;
            var += x * x;
        }
        mean /= n as f64;
        var = var / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
