//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Replaces `serde_json` (not available offline). Supports the full JSON
//! grammar minus exotic escapes (`\uXXXX` is handled for the BMP). Used by
//! geometry config files, the artifact manifest and the coordinator's
//! line-delimited JSON protocol.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as `f64` (sufficient for configs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Convenience: `get(key)` then `as_f64`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }
    /// Parse `[f64, ...]`.
    pub fn get_f64_vec(&self, key: &str) -> Option<Vec<f64>> {
        let arr = self.get(key)?.as_arr()?;
        arr.iter().map(|v| v.as_f64()).collect()
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number at {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape: {e}"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "1e3", "\"hi\""] {
            let v = parse(s).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-2}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get_f64("d"), Some(-0.025));
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn helpers() {
        let v = parse(r#"{"n": 5, "s": "x", "v": [1.0, 2.0]}"#).unwrap();
        assert_eq!(v.get_usize("n"), Some(5));
        assert_eq!(v.get_str("s"), Some("x"));
        assert_eq!(v.get_f64_vec("v"), Some(vec![1.0, 2.0]));
        assert_eq!(v.get("missing"), None);
    }
}
