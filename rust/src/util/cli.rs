//! Tiny command-line parser (clap replacement).
//!
//! Supports `leap <subcommand> --key value --flag` style invocations. Typed
//! getters with defaults keep the CLI code in `main.rs` compact.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args and `--key value`
/// options (a `--key` followed by another `--` or end-of-args is a flag).
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.str_opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.str_opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.str_opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("project out.raw --nx 256 --geometry parallel --verbose");
        assert_eq!(a.subcommand, "project");
        assert_eq!(a.usize_or("nx", 0), 256);
        assert_eq!(a.str_or("geometry", ""), "parallel");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.raw"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("fbp");
        assert_eq!(a.usize_or("nx", 128), 128);
        assert_eq!(a.f64_or("pitch", 1.5), 1.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("x --a --b 3");
        assert!(a.flag("a"));
        assert_eq!(a.usize_or("b", 0), 3);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, "");
        assert!(a.flag("help"));
    }

    #[test]
    fn negative_number_value() {
        // a value starting with '-' but not '--' is still a value
        let a = parse("x --offset -1.5");
        assert_eq!(a.f64_or("offset", 0.0), -1.5);
    }
}
