//! Self-contained substrates: PRNG, JSON, thread-pool, CLI parsing.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so every other substrate this repo needs is implemented here from
//! scratch. Each submodule is small, tested and dependency-free.

pub mod rng;
pub mod json;
pub mod netpoll;
pub mod pool;
pub mod cli;
pub mod fft;

/// Clamp a float into `[lo, hi]`.
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Relative L2 error `‖a − b‖ / max(‖b‖, eps)` between two slices.
pub fn rel_l2(a: &[f32], b: &[f32], eps: f64) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2: length mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let d = (x - y) as f64;
        num += d * d;
        den += (y as f64) * (y as f64);
    }
    (num.sqrt()) / den.sqrt().max(eps)
}

/// Dot product of two `f32` slices accumulated in `f64` — used by the
/// adjoint `⟨Ax, y⟩ = ⟨x, Aᵀy⟩` tests where f32 accumulation would swamp
/// the signal.
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot_f64: length mismatch");
    a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clampf_bounds() {
        assert_eq!(clampf(-1.0, 0.0, 2.0), 0.0);
        assert_eq!(clampf(3.0, 0.0, 2.0), 2.0);
        assert_eq!(clampf(1.5, 0.0, 2.0), 1.5);
    }

    #[test]
    fn rel_l2_zero_for_identical() {
        let a = [1.0f32, 2.0, 3.0];
        assert!(rel_l2(&a, &a, 1e-12) < 1e-12);
    }

    #[test]
    fn dot_matches_manual() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot_f64(&a, &b), 32.0);
    }
}
