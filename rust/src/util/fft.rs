//! Radix-2 complex FFT (iterative Cooley-Tukey) + real-signal helpers.
//!
//! Built for the FBP/FDK ramp filtering in [`crate::recon::filters`]:
//! sinogram rows are zero-padded to the next power of two, filtered in the
//! frequency domain and inverse-transformed. Accuracy is f64 throughout —
//! filtering error must sit well below projector discretization error.

use std::f64::consts::PI;

/// In-place complex FFT of `(re, im)`. `inverse=true` applies the 1/n
/// normalization. Length must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length {n} must be a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = i.reverse_bits() >> (usize::BITS - bits);
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for j in 0..len / 2 {
                let a = i + j;
                let b = i + j + len / 2;
                let tr = re[b] * cr - im[b] * ci;
                let ti = re[b] * ci + im[b] * cr;
                re[b] = re[a] - tr;
                im[b] = im[a] - ti;
                re[a] += tr;
                im[a] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f64;
        for i in 0..n {
            re[i] *= inv;
            im[i] *= inv;
        }
    }
}

/// Next power of two ≥ `n`.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Filter a real signal with a real, even frequency response.
///
/// `signal` is zero-padded to `nfft ≥ 2·len` (caller chooses), transformed,
/// multiplied by `freq_response[k]` (length `nfft`), inverse-transformed and
/// truncated back to `len`.
pub fn filter_real(signal: &[f32], freq_response: &[f64], out: &mut [f32]) {
    let nfft = freq_response.len();
    assert!(nfft.is_power_of_two());
    assert!(signal.len() <= nfft);
    assert_eq!(signal.len(), out.len());
    let mut re = vec![0.0f64; nfft];
    let mut im = vec![0.0f64; nfft];
    for (i, &s) in signal.iter().enumerate() {
        re[i] = s as f64;
    }
    fft_inplace(&mut re, &mut im, false);
    for k in 0..nfft {
        re[k] *= freq_response[k];
        im[k] *= freq_response[k];
    }
    fft_inplace(&mut re, &mut im, true);
    for i in 0..out.len() {
        out[i] = re[i] as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_roundtrip() {
        let n = 64;
        let mut re: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut im = vec![0.0; n];
        let orig = re.clone();
        fft_inplace(&mut re, &mut im, false);
        fft_inplace(&mut re, &mut im, true);
        for i in 0..n {
            assert!((re[i] - orig[i]).abs() < 1e-12, "i={i}");
            assert!(im[i].abs() < 1e-12);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let n = 16;
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[0] = 1.0;
        fft_inplace(&mut re, &mut im, false);
        for k in 0..n {
            assert!((re[k] - 1.0).abs() < 1e-12);
            assert!(im[k].abs() < 1e-12);
        }
    }

    #[test]
    fn fft_single_tone_peaks_at_bin() {
        let n = 128;
        let f = 5;
        let mut re: Vec<f64> =
            (0..n).map(|i| (2.0 * PI * f as f64 * i as f64 / n as f64).cos()).collect();
        let mut im = vec![0.0; n];
        fft_inplace(&mut re, &mut im, false);
        let mag: Vec<f64> = (0..n).map(|k| (re[k] * re[k] + im[k] * im[k]).sqrt()).collect();
        let peak = mag.iter().cloned().fold(0.0, f64::max);
        assert!((mag[f] - n as f64 / 2.0).abs() < 1e-9);
        assert!((peak - mag[f]).abs() < 1e-9);
    }

    #[test]
    fn identity_filter_is_identity() {
        let sig: Vec<f32> = (0..50).map(|i| (i as f32 * 0.1).cos()).collect();
        let nfft = next_pow2(2 * sig.len());
        let resp = vec![1.0f64; nfft];
        let mut out = vec![0.0f32; sig.len()];
        filter_real(&sig, &resp, &mut out);
        for i in 0..sig.len() {
            assert!((out[i] - sig[i]).abs() < 1e-5);
        }
    }
}
