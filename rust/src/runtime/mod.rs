//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from the Rust hot path.
//!
//! `python/compile/aot.py` lowers each L2 entry point to HLO *text* (the
//! only interchange format the image's xla_extension 0.5.1 accepts from
//! jax ≥ 0.5 — see DESIGN.md) plus a `manifest.json` describing shapes.
//! The [`Engine`] compiles every entry once at startup; per-request cost
//! is one host-to-device copy per input and one execute call, mirroring
//! the paper's "data already on the GPU" fast path.
//!
//! ## The `pjrt` cargo feature
//!
//! The real engine depends on the vendored `xla` crate (PJRT bindings),
//! which is only present in the full build environment. It is gated
//! behind the **`pjrt`** feature (off by default):
//!
//! * `--features pjrt` — compiles the real [`Engine`]/[`EngineHost`]
//!   (requires the `xla` dependency to be uncommented in `Cargo.toml`).
//! * default — a stub with the identical API whose constructors return a
//!   descriptive error, so the native projector path, the solvers, the
//!   coordinator and the full test suite build and run without the XLA
//!   closure. Callers already treat `Engine::load` as fallible (artifacts
//!   may simply not be built), so the stub degrades every consumer to its
//!   documented "native only" path.

/// Shapes of the artifact set (matches `python/compile/config.ScanSpec`).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub n: usize,
    pub nviews: usize,
    pub ncols: usize,
    pub voxel: f64,
    pub du: f64,
    pub arc_deg: f64,
}

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
pub use engine::{Engine, EngineHost, Entry};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, EngineHost, Entry};
