//! The real PJRT engine — compiled only with `--features pjrt` (needs the
//! vendored `xla` crate). See the module docs in `runtime/mod.rs`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::ArtifactSpec;
use crate::util::json::{parse, Json};

/// One compiled entry point.
pub struct Entry {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// The artifact engine: a PJRT CPU client plus all compiled entries.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub spec: ArtifactSpec,
    entries: HashMap<String, Entry>,
    dir: PathBuf,
}

fn shapes_from(json: &Json, key: &str) -> Result<Vec<Vec<usize>>> {
    let arr = json
        .get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("manifest entry missing {key}"))?;
    arr.iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("bad shape"))
                .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
        })
        .collect()
}

impl Engine {
    /// Load every artifact listed in `dir/manifest.json` and compile it on
    /// the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let spec_json = manifest.get("spec").ok_or_else(|| anyhow!("manifest missing spec"))?;
        let spec = ArtifactSpec {
            n: spec_json.get_usize("n").unwrap_or(0),
            nviews: spec_json.get_usize("nviews").unwrap_or(0),
            ncols: spec_json.get_usize("ncols").unwrap_or(0),
            voxel: spec_json.get_f64("voxel").unwrap_or(1.0),
            du: spec_json.get_f64("du").unwrap_or(1.0),
            arc_deg: spec_json.get_f64("arc_deg").unwrap_or(180.0),
        };
        let client = xla::PjRtClient::cpu()?;
        let mut entries = HashMap::new();
        let entry_map = manifest
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        for (name, meta) in entry_map {
            let file = meta.get_str("file").ok_or_else(|| anyhow!("{name}: missing file"))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    exe,
                    input_shapes: shapes_from(meta, "inputs")?,
                    output_shapes: shapes_from(meta, "outputs")?,
                },
            );
        }
        Ok(Engine { client, spec, entries, dir })
    }

    /// Artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.get(name)
    }

    /// Execute entry `name` on f32 buffers (shapes validated against the
    /// manifest). Returns one f32 buffer per output.
    pub fn run(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact entry {name} (have: {:?})", self.entry_names()))?;
        if inputs.len() != entry.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                entry.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(entry.input_shapes.iter()) {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                bail!("{name}: input length {} != shape {:?}", buf.len(), shape);
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = entry.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple()?;
        if parts.len() != entry.output_shapes.len() {
            bail!("{name}: got {} outputs, expected {}", parts.len(), entry.output_shapes.len());
        }
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Convenience: run a single-output entry.
    pub fn run1(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let mut out = self.run(name, inputs)?;
        if out.len() != 1 {
            bail!("{name}: expected single output, got {}", out.len());
        }
        Ok(out.pop().unwrap())
    }
}

/// Thread-hosted engine: the `xla` crate's PJRT handles are `!Send`
/// (`Rc` internals), so the engine lives on a dedicated thread and the
/// coordinator's worker pool talks to it over a channel. This also
/// serializes device access — correct for the single CPU PJRT device, and
/// the same discipline a single-GPU deployment needs.
pub struct EngineHost {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<HostCmd>>,
    pub spec: ArtifactSpec,
    entry_meta: HashMap<String, (Vec<Vec<usize>>, Vec<Vec<usize>>)>,
    _thread: std::thread::JoinHandle<()>,
}

enum HostCmd {
    Run {
        op: String,
        inputs: Vec<Vec<f32>>,
        reply: std::sync::mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
}

impl EngineHost {
    /// Load the artifacts on a dedicated engine thread.
    pub fn load(dir: impl AsRef<Path>) -> Result<EngineHost> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = std::sync::mpsc::channel::<HostCmd>();
        let (init_tx, init_rx) = std::sync::mpsc::channel();
        let thread = std::thread::spawn(move || {
            let engine = match Engine::load(&dir) {
                Ok(e) => {
                    let meta: HashMap<String, (Vec<Vec<usize>>, Vec<Vec<usize>>)> = e
                        .entries
                        .iter()
                        .map(|(k, v)| (k.clone(), (v.input_shapes.clone(), v.output_shapes.clone())))
                        .collect();
                    let _ = init_tx.send(Ok((e.spec.clone(), meta)));
                    e
                }
                Err(err) => {
                    let _ = init_tx.send(Err(err));
                    return;
                }
            };
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    HostCmd::Run { op, inputs, reply } => {
                        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
                        let _ = reply.send(engine.run(&op, &refs));
                    }
                }
            }
        });
        let (spec, entry_meta) = init_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during init"))??;
        Ok(EngineHost { tx: std::sync::Mutex::new(tx), spec, entry_meta, _thread: thread })
    }

    /// Execute an entry through the engine thread.
    pub fn run(&self, op: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(HostCmd::Run {
                op: op.to_string(),
                inputs: inputs.iter().map(|b| b.to_vec()).collect(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    pub fn run1(&self, op: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let mut out = self.run(op, inputs)?;
        anyhow::ensure!(out.len() == 1, "{op}: expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entry_meta.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn shapes(&self, op: &str) -> Option<&(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
        self.entry_meta.get(op)
    }
}

#[cfg(test)]
mod tests {
    // Engine execution tests live in rust/tests/runtime_integration.rs
    // (they need artifacts built by `make artifacts`); here we test the
    // manifest plumbing only.
    use super::*;

    #[test]
    fn missing_manifest_is_helpful() {
        let err = match Engine::load("/nonexistent_dir_xyz") {
            Err(e) => e,
            Ok(_) => panic!("load should fail"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn shapes_from_parses() {
        let j = parse(r#"{"inputs": [[2, 3], [4]]}"#).unwrap();
        let s = shapes_from(&j, "inputs").unwrap();
        assert_eq!(s, vec![vec![2, 3], vec![4]]);
        assert!(shapes_from(&j, "outputs").is_err());
    }
}
