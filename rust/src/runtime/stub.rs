//! Clear-error stand-in for the PJRT engine, used when the `pjrt` cargo
//! feature is off (the default). Same API surface as `runtime::engine`;
//! every constructor fails with a message explaining how to enable the
//! real runtime, so callers fall back to their documented native paths.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::ArtifactSpec;

fn unavailable(what: &str) -> anyhow::Error {
    anyhow!(
        "{what}: this build has no PJRT runtime (the `pjrt` cargo feature is off). \
         Rebuild with `cargo build --features pjrt` and the vendored `xla` crate \
         to execute AOT artifacts; the native Rust projectors cover every op \
         without it."
    )
}

/// One compiled entry point (metadata only in the stub).
pub struct Entry {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Stub artifact engine; [`Engine::load`] always fails.
pub struct Engine {
    pub spec: ArtifactSpec,
    entries: HashMap<String, Entry>,
    dir: PathBuf,
}

impl Engine {
    /// Always fails: the `pjrt` feature is off in this build.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let _ = dir.as_ref();
        Err(unavailable("runtime::Engine::load"))
    }

    /// Artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.get(name)
    }

    pub fn run(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(name))
    }

    pub fn run1(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Err(unavailable(name))
    }
}

/// Stub thread-hosted engine; [`EngineHost::load`] always fails.
pub struct EngineHost {
    pub spec: ArtifactSpec,
    entry_meta: HashMap<String, (Vec<Vec<usize>>, Vec<Vec<usize>>)>,
}

impl EngineHost {
    /// Always fails: the `pjrt` feature is off in this build.
    pub fn load(dir: impl AsRef<Path>) -> Result<EngineHost> {
        let _ = dir.as_ref();
        Err(unavailable("runtime::EngineHost::load"))
    }

    pub fn run(&self, op: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(op))
    }

    pub fn run1(&self, op: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Err(unavailable(op))
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entry_meta.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn shapes(&self, op: &str) -> Option<&(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
        self.entry_meta.get(op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_error_names_the_feature() {
        let err = Engine::load("artifacts").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        let err = EngineHost::load("artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }
}
