//! File I/O: raw little-endian `f32` arrays (the library's native
//! interchange, matching the paper's "contiguous 32-bit floating point
//! arrays"), 16-bit PGM image dumps for quick inspection, and JSON run
//! records used by EXPERIMENTS.md.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::array::{Sino, Vol3};
use crate::util::json::Json;

/// Write a raw little-endian f32 buffer.
pub fn write_f32<P: AsRef<Path>>(path: P, data: &[f32]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()
}

/// Read a raw little-endian f32 buffer of exactly `len` elements.
pub fn read_f32<P: AsRef<Path>>(path: P, len: usize) -> std::io::Result<Vec<f32>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut bytes = vec![0u8; len * 4];
    r.read_exact(&mut bytes)?;
    // reject trailing data — size mismatches are config bugs
    let mut extra = [0u8; 1];
    if r.read(&mut extra)? != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("file longer than expected {len} f32 elements"),
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a volume as `path.raw` plus a `path.json` sidecar with dimensions.
pub fn save_vol<P: AsRef<Path>>(path: P, vol: &Vol3) -> std::io::Result<()> {
    let p = path.as_ref();
    write_f32(p, &vol.data)?;
    let meta = Json::obj(vec![
        ("kind", Json::Str("volume".into())),
        ("nx", Json::Num(vol.nx as f64)),
        ("ny", Json::Num(vol.ny as f64)),
        ("nz", Json::Num(vol.nz as f64)),
    ]);
    std::fs::write(p.with_extension("json"), meta.to_string())
}

/// Load a volume saved by [`save_vol`].
pub fn load_vol<P: AsRef<Path>>(path: P) -> std::io::Result<Vol3> {
    let p = path.as_ref();
    let meta_text = std::fs::read_to_string(p.with_extension("json"))?;
    let meta = crate::util::json::parse(&meta_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let nx = meta.get_usize("nx").unwrap_or(0);
    let ny = meta.get_usize("ny").unwrap_or(0);
    let nz = meta.get_usize("nz").unwrap_or(1);
    let data = read_f32(p, nx * ny * nz)?;
    Ok(Vol3::from_vec(nx, ny, nz, data))
}

/// Save a sinogram as raw f32 + JSON sidecar.
pub fn save_sino<P: AsRef<Path>>(path: P, sino: &Sino) -> std::io::Result<()> {
    let p = path.as_ref();
    write_f32(p, &sino.data)?;
    let meta = Json::obj(vec![
        ("kind", Json::Str("sino".into())),
        ("nviews", Json::Num(sino.nviews as f64)),
        ("nrows", Json::Num(sino.nrows as f64)),
        ("ncols", Json::Num(sino.ncols as f64)),
    ]);
    std::fs::write(p.with_extension("json"), meta.to_string())
}

/// Load a sinogram saved by [`save_sino`].
pub fn load_sino<P: AsRef<Path>>(path: P) -> std::io::Result<Sino> {
    let p = path.as_ref();
    let meta_text = std::fs::read_to_string(p.with_extension("json"))?;
    let meta = crate::util::json::parse(&meta_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let nviews = meta.get_usize("nviews").unwrap_or(0);
    let nrows = meta.get_usize("nrows").unwrap_or(1);
    let ncols = meta.get_usize("ncols").unwrap_or(0);
    let data = read_f32(p, nviews * nrows * ncols)?;
    Ok(Sino::from_vec(nviews, nrows, ncols, data))
}

/// Write a 2-D image (row-major, `ny` rows of `nx`) as a 16-bit PGM with
/// min/max windowing — handy for eyeballing reconstructions.
pub fn write_pgm16<P: AsRef<Path>>(path: P, img: &[f32], nx: usize, ny: usize) -> std::io::Result<()> {
    assert_eq!(img.len(), nx * ny);
    let lo = img.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = img.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { 65535.0 / (hi - lo) } else { 0.0 };
    let mut w = BufWriter::new(File::create(path)?);
    write!(w, "P5\n{nx} {ny}\n65535\n")?;
    for &v in img {
        let q = (((v - lo) * scale) as u16).to_be_bytes();
        w.write_all(&q)?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("leap_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn raw_f32_roundtrip() {
        let d = tmpdir();
        let p = d.join("a.raw");
        let data = vec![1.5f32, -2.25, 0.0, 1e-10];
        write_f32(&p, &data).unwrap();
        let back = read_f32(&p, 4).unwrap();
        assert_eq!(data, back);
        // wrong length must error
        assert!(read_f32(&p, 3).is_err());
        assert!(read_f32(&p, 5).is_err());
    }

    #[test]
    fn vol_roundtrip_with_sidecar() {
        let d = tmpdir();
        let p = d.join("vol.raw");
        let mut v = Vol3::zeros(3, 4, 2);
        *v.at_mut(1, 2, 1) = 7.5;
        save_vol(&p, &v).unwrap();
        let back = load_vol(&p).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn sino_roundtrip_with_sidecar() {
        let d = tmpdir();
        let p = d.join("sino.raw");
        let mut s = Sino::zeros(5, 2, 3);
        *s.at_mut(4, 1, 2) = -3.25;
        save_sino(&p, &s).unwrap();
        let back = load_sino(&p).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn pgm_has_header_and_size() {
        let d = tmpdir();
        let p = d.join("img.pgm");
        let img = vec![0.0f32, 0.5, 1.0, 0.25];
        write_pgm16(&p, &img, 2, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n65535\n"));
        assert_eq!(bytes.len(), 13 + 8);
    }
}
