//! Minimal benchmarking harness (criterion replacement — the vendored
//! crate set has no criterion). Used by every `rust/benches/*.rs` target
//! (`harness = false`).
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met; reports
//! mean / median / p10 / p90 and allows custom throughput annotation.
//! Results can be appended as JSON lines for EXPERIMENTS.md bookkeeping.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    /// Optional label → value annotations (e.g. memory bytes, Mvox/s).
    pub notes: Vec<(String, f64)>,
}

impl Measurement {
    pub fn print(&self) {
        print!(
            "{:<44} {:>10.4} s  (median {:.4}, p10 {:.4}, p90 {:.4}, n={})",
            self.name, self.mean_s, self.median_s, self.p10_s, self.p90_s, self.iters
        );
        for (k, v) in &self.notes {
            if *v >= 1e9 {
                print!("  {k}={:.3}G", v / 1e9);
            } else if *v >= 1e6 {
                print!("  {k}={:.3}M", v / 1e6);
            } else if *v >= 1e3 {
                print!("  {k}={:.3}k", v / 1e3);
            } else {
                print!("  {k}={v:.3}");
            }
        }
        println!();
    }

    pub fn to_json_line(&self) -> String {
        use crate::util::json::Json;
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("median_s", Json::Num(self.median_s)),
            ("p10_s", Json::Num(self.p10_s)),
            ("p90_s", Json::Num(self.p90_s)),
        ];
        for (k, v) in &self.notes {
            fields.push((k.as_str(), Json::Num(*v)));
        }
        // keys must live long enough: rebuild with owned keys
        let obj: std::collections::BTreeMap<String, Json> =
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        Json::Obj(obj).to_string()
    }
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, min_iters: 3, max_iters: 50, min_time: Duration::from_millis(300) }
    }
}

impl Bench {
    /// Quick preset for expensive end-to-end cases.
    pub fn quick() -> Bench {
        Bench { warmup: 1, min_iters: 2, max_iters: 5, min_time: Duration::from_millis(50) }
    }

    /// Single-iteration preset: CI smoke runs (see [`smoke_mode`]) only
    /// check that bench targets still execute, not their timings.
    pub fn smoke() -> Bench {
        Bench { warmup: 0, min_iters: 1, max_iters: 1, min_time: Duration::ZERO }
    }

    /// Time `f`, which must fully perform the work each call (return value
    /// is black-boxed).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (start.elapsed() < self.min_time && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let q = |p: f64| times[((n as f64 - 1.0) * p).round() as usize];
        Measurement {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            median_s: q(0.5),
            p10_s: q(0.1),
            p90_s: q(0.9),
            notes: vec![],
        }
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// `LEAP_BENCH_SMOKE` is set (to anything but `0`): bench mains should
/// run one iteration of each case so CI can keep the targets honest
/// without paying for real measurements.
pub fn smoke_mode() -> bool {
    std::env::var("LEAP_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Append measurements as JSON lines to an arbitrary file — the perf
/// trajectory files checked into the repo root (e.g. `BENCH_PR2.json`)
/// use this so every bench run extends the history.
pub fn append_results_to(path: &str, measurements: &[Measurement]) {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        use std::io::Write;
        for m in measurements {
            let _ = writeln!(f, "{}", m.to_json_line());
        }
    }
}

/// Append measurements to `target/bench_results.jsonl` for later analysis.
pub fn append_results(measurements: &[Measurement]) {
    append_results_to("target/bench_results.jsonl", measurements);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_something() {
        let b = Bench { warmup: 0, min_iters: 3, max_iters: 3, min_time: Duration::ZERO };
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(m.iters, 3);
        assert!(m.mean_s > 0.0);
        assert!(m.p10_s <= m.median_s && m.median_s <= m.p90_s);
    }

    #[test]
    fn json_line_parses() {
        let mut m = Measurement {
            name: "x".into(),
            iters: 5,
            mean_s: 0.5,
            median_s: 0.4,
            p10_s: 0.3,
            p90_s: 0.9,
            notes: vec![("mem_bytes".into(), 1024.0)],
        };
        m.notes.push(("rate".into(), 2.0));
        let j = crate::util::json::parse(&m.to_json_line()).unwrap();
        assert_eq!(j.get_f64("mem_bytes"), Some(1024.0));
        assert_eq!(j.get_str("name"), Some("x"));
    }
}
