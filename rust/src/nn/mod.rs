//! `leap::nn` — direct convolution kernels and their exact VJPs for the
//! tape's neural node kinds.
//!
//! The tape ([`crate::tape`]) composes projectors and elementwise glue;
//! learned iterative reconstruction (ItNet / learned primal-dual, the
//! "near-exact recovery" recipe of Genzel et al.) additionally needs
//! small per-iteration CNN regularizers. This module holds the float
//! kernels those node kinds evaluate:
//!
//! * [`conv2d_forward`] / [`conv3d_forward`] — stride-1, same-padding
//!   (`k` odd, zero padding) **cross-correlation** with per-output-channel
//!   bias, written as direct gather loops (no im2col buffer: the tape
//!   keeps every node value alive for the backward sweep, so transient
//!   `k²·cin`-fold input expansions would dominate memory for nothing).
//! * [`conv2d_input_grad`] / [`conv2d_weight_grad`] / [`conv2d_bias_grad`]
//!   (and the 3-D versions) — the three exact VJPs. Input and weight
//!   gradients are *gather* loops (each output cell reads, nothing
//!   scatters), so they parallelize safely and accumulate in a fixed
//!   sequential order per cell — bit-deterministic like the rest of the
//!   tape. Weight/bias gradients reduce over the whole image per tap, so
//!   they accumulate in f64 and cast once (the same policy as
//!   `Scale`'s scalar gradient).
//! * [`avg_pool_forward`] / [`avg_pool_input_grad`],
//!   [`upsample_forward`] / [`upsample_input_grad`] — factor-`f`
//!   spatial block mean / nearest-neighbour replication per channel
//!   slab. The two are exact adjoints of each other up to the `1/f²`
//!   mean weight (asserted in the tests).
//!
//! ## Layout
//!
//! Tensors follow the crate's volume convention (`[z][y][x]`, dim 0
//! fastest — see `lib.rs`): an image tensor of [`crate::ops::Shape`]
//! `[w, h, c]` stores channel slab `c` as `h` contiguous rows of `w`,
//! i.e. `idx = (c·h + y)·w + x`. A single-slice volume `[n, n, 1]` is
//! therefore a 1-channel image with **no reshape**. 3-D stacks put the
//! channel axis outside z: shape `[w, h, cin·nz]`, `idx = ((ci·nz +
//! z)·h + y)·w + x` — again, a raw volume is the `cin = 1` case.
//! Weights are `[kᵈ, cin, cout]` with tap fastest: 2-D
//! `idx = (co·cin + ci)·k² + ky·k + kx`, 3-D
//! `idx = (co·cin + ci)·k³ + (kz·k + ky)·k + kx`. Bias is `[cout, 1, 1]`.

use crate::util::rng::Rng;

/// He-uniform initialization for a convolution weight tensor with
/// `taps` spatial taps (`k²` or `k³`) per input channel: uniform on
/// `±sqrt(6 / (taps·cin))`, the fan-in bound that keeps relu activations
/// unit-scale at depth. Deterministic in `seed` (xoshiro via
/// [`Rng::new`]) — two corpora trained from the same seed are
/// bit-identical.
pub fn conv_init(seed: u64, taps: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut w = vec![0.0f32; taps * cin * cout];
    let bound = (6.0 / (taps * cin) as f64).sqrt() as f32;
    Rng::new(seed ^ 0x6e6e_5f63_6f6e_7631).fill_uniform(&mut w, -bound, bound);
    w
}

/// 2-D same-padding cross-correlation.
/// `x`: `[w, h, cin]`, `wt`: `[k², cin, cout]`, `b`: `[cout]`,
/// `out`: `[w, h, cout]` (overwritten). `k` must be odd.
pub fn conv2d_forward(
    x: &[f32],
    wt: &[f32],
    b: &[f32],
    w: usize,
    h: usize,
    cin: usize,
    cout: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), w * h * cin);
    debug_assert_eq!(wt.len(), k * k * cin * cout);
    debug_assert_eq!(b.len(), cout);
    debug_assert_eq!(out.len(), w * h * cout);
    debug_assert_eq!(k % 2, 1);
    let p = (k / 2) as isize;
    let kk = k * k;
    for co in 0..cout {
        for y in 0..h {
            for xx in 0..w {
                let mut acc = b[co];
                for ci in 0..cin {
                    let xbase = ci * h * w;
                    let wbase = (co * cin + ci) * kk;
                    for ky in 0..k {
                        let iy = y as isize + ky as isize - p;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let xrow = xbase + iy as usize * w;
                        let wrow = wbase + ky * k;
                        for kx in 0..k {
                            let ix = xx as isize + kx as isize - p;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += wt[wrow + kx] * x[xrow + ix as usize];
                        }
                    }
                }
                out[(co * h + y) * w + xx] = acc;
            }
        }
    }
}

/// VJP of [`conv2d_forward`] w.r.t. its input: `dx[ci, y, x] += Σ_co
/// Σ_taps wt[co, ci, tap] · dy[co, y − oy, x − ox]` — a gather per input
/// cell (the correlation with the spatially-flipped kernel, summed over
/// output channels). Accumulates **into** `dx`.
pub fn conv2d_input_grad(
    dy: &[f32],
    wt: &[f32],
    w: usize,
    h: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), w * h * cout);
    debug_assert_eq!(wt.len(), k * k * cin * cout);
    debug_assert_eq!(dx.len(), w * h * cin);
    let p = (k / 2) as isize;
    let kk = k * k;
    for ci in 0..cin {
        for y in 0..h {
            for xx in 0..w {
                let mut acc = 0.0f32;
                for co in 0..cout {
                    let dbase = co * h * w;
                    let wbase = (co * cin + ci) * kk;
                    for ky in 0..k {
                        // forward read x[y + ky − p] into out[y], so this
                        // input cell feeds out[y − ky + p]
                        let oy = y as isize - (ky as isize - p);
                        if oy < 0 || oy >= h as isize {
                            continue;
                        }
                        let drow = dbase + oy as usize * w;
                        let wrow = wbase + ky * k;
                        for kx in 0..k {
                            let ox = xx as isize - (kx as isize - p);
                            if ox < 0 || ox >= w as isize {
                                continue;
                            }
                            acc += wt[wrow + kx] * dy[drow + ox as usize];
                        }
                    }
                }
                dx[(ci * h + y) * w + xx] += acc;
            }
        }
    }
}

/// VJP of [`conv2d_forward`] w.r.t. the weights: `dw[co, ci, ky, kx] +=
/// Σ_{y,x} dy[co, y, x] · x[ci, y + ky − p, x + kx − p]`. One f64
/// whole-image reduction per tap, cast once — deterministic and
/// FD-tight even on large images. Accumulates **into** `dw`.
pub fn conv2d_weight_grad(
    x: &[f32],
    dy: &[f32],
    w: usize,
    h: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(x.len(), w * h * cin);
    debug_assert_eq!(dy.len(), w * h * cout);
    debug_assert_eq!(dw.len(), k * k * cin * cout);
    let p = (k / 2) as isize;
    let kk = k * k;
    for co in 0..cout {
        let dbase = co * h * w;
        for ci in 0..cin {
            let xbase = ci * h * w;
            for ky in 0..k {
                for kx in 0..k {
                    let mut acc = 0.0f64;
                    for y in 0..h {
                        let iy = y as isize + ky as isize - p;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let drow = dbase + y * w;
                        let xrow = xbase + iy as usize * w;
                        for xx in 0..w {
                            let ix = xx as isize + kx as isize - p;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += dy[drow + xx] as f64 * x[xrow + ix as usize] as f64;
                        }
                    }
                    dw[(co * cin + ci) * kk + ky * k + kx] += acc as f32;
                }
            }
        }
    }
}

/// VJP of [`conv2d_forward`] w.r.t. the bias: `db[co] += Σ_{y,x}
/// dy[co, y, x]` (f64 reduction, cast once). Accumulates **into** `db`.
pub fn conv2d_bias_grad(dy: &[f32], w: usize, h: usize, cout: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), w * h * cout);
    debug_assert_eq!(db.len(), cout);
    for co in 0..cout {
        let mut acc = 0.0f64;
        for &v in &dy[co * h * w..(co + 1) * h * w] {
            acc += v as f64;
        }
        db[co] += acc as f32;
    }
}

/// 3-D same-padding cross-correlation over `nz` z-slabs.
/// `x`: `[w, h, cin·nz]`, `wt`: `[k³, cin, cout]`, `b`: `[cout]`,
/// `out`: `[w, h, cout·nz]` (overwritten). `k` must be odd.
#[allow(clippy::too_many_arguments)]
pub fn conv3d_forward(
    x: &[f32],
    wt: &[f32],
    b: &[f32],
    w: usize,
    h: usize,
    nz: usize,
    cin: usize,
    cout: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), w * h * nz * cin);
    debug_assert_eq!(wt.len(), k * k * k * cin * cout);
    debug_assert_eq!(b.len(), cout);
    debug_assert_eq!(out.len(), w * h * nz * cout);
    debug_assert_eq!(k % 2, 1);
    let p = (k / 2) as isize;
    let k3 = k * k * k;
    for co in 0..cout {
        for z in 0..nz {
            for y in 0..h {
                for xx in 0..w {
                    let mut acc = b[co];
                    for ci in 0..cin {
                        let wbase = (co * cin + ci) * k3;
                        for kz in 0..k {
                            let iz = z as isize + kz as isize - p;
                            if iz < 0 || iz >= nz as isize {
                                continue;
                            }
                            let xslab = ((ci * nz + iz as usize) * h) * w;
                            for ky in 0..k {
                                let iy = y as isize + ky as isize - p;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                let xrow = xslab + iy as usize * w;
                                let wrow = wbase + (kz * k + ky) * k;
                                for kx in 0..k {
                                    let ix = xx as isize + kx as isize - p;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    acc += wt[wrow + kx] * x[xrow + ix as usize];
                                }
                            }
                        }
                    }
                    out[((co * nz + z) * h + y) * w + xx] = acc;
                }
            }
        }
    }
}

/// VJP of [`conv3d_forward`] w.r.t. its input (gather per input cell).
/// Accumulates **into** `dx`.
#[allow(clippy::too_many_arguments)]
pub fn conv3d_input_grad(
    dy: &[f32],
    wt: &[f32],
    w: usize,
    h: usize,
    nz: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), w * h * nz * cout);
    debug_assert_eq!(wt.len(), k * k * k * cin * cout);
    debug_assert_eq!(dx.len(), w * h * nz * cin);
    let p = (k / 2) as isize;
    let k3 = k * k * k;
    for ci in 0..cin {
        for z in 0..nz {
            for y in 0..h {
                for xx in 0..w {
                    let mut acc = 0.0f32;
                    for co in 0..cout {
                        let wbase = (co * cin + ci) * k3;
                        for kz in 0..k {
                            let oz = z as isize - (kz as isize - p);
                            if oz < 0 || oz >= nz as isize {
                                continue;
                            }
                            let dslab = ((co * nz + oz as usize) * h) * w;
                            for ky in 0..k {
                                let oy = y as isize - (ky as isize - p);
                                if oy < 0 || oy >= h as isize {
                                    continue;
                                }
                                let drow = dslab + oy as usize * w;
                                let wrow = wbase + (kz * k + ky) * k;
                                for kx in 0..k {
                                    let ox = xx as isize - (kx as isize - p);
                                    if ox < 0 || ox >= w as isize {
                                        continue;
                                    }
                                    acc += wt[wrow + kx] * dy[drow + ox as usize];
                                }
                            }
                        }
                    }
                    dx[((ci * nz + z) * h + y) * w + xx] += acc;
                }
            }
        }
    }
}

/// VJP of [`conv3d_forward`] w.r.t. the weights (f64 per-tap reduction,
/// cast once). Accumulates **into** `dw`.
#[allow(clippy::too_many_arguments)]
pub fn conv3d_weight_grad(
    x: &[f32],
    dy: &[f32],
    w: usize,
    h: usize,
    nz: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(x.len(), w * h * nz * cin);
    debug_assert_eq!(dy.len(), w * h * nz * cout);
    debug_assert_eq!(dw.len(), k * k * k * cin * cout);
    let p = (k / 2) as isize;
    let k3 = k * k * k;
    for co in 0..cout {
        for ci in 0..cin {
            for kz in 0..k {
                for ky in 0..k {
                    for kx in 0..k {
                        let mut acc = 0.0f64;
                        for z in 0..nz {
                            let iz = z as isize + kz as isize - p;
                            if iz < 0 || iz >= nz as isize {
                                continue;
                            }
                            for y in 0..h {
                                let iy = y as isize + ky as isize - p;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                let drow = ((co * nz + z) * h + y) * w;
                                let xrow = ((ci * nz + iz as usize) * h + iy as usize) * w;
                                for xx in 0..w {
                                    let ix = xx as isize + kx as isize - p;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    acc += dy[drow + xx] as f64 * x[xrow + ix as usize] as f64;
                                }
                            }
                        }
                        dw[(co * cin + ci) * k3 + (kz * k + ky) * k + kx] += acc as f32;
                    }
                }
            }
        }
    }
}

/// VJP of [`conv3d_forward`] w.r.t. the bias (f64 reduction, cast once).
/// Accumulates **into** `db`.
pub fn conv3d_bias_grad(dy: &[f32], w: usize, h: usize, nz: usize, cout: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), w * h * nz * cout);
    debug_assert_eq!(db.len(), cout);
    for co in 0..cout {
        let mut acc = 0.0f64;
        for &v in &dy[co * nz * h * w..(co + 1) * nz * h * w] {
            acc += v as f64;
        }
        db[co] += acc as f32;
    }
}

/// Factor-`f` average pooling per channel slab: `out[c, y, x]` is the
/// mean of the `f×f` input block. `w` and `h` must be divisible by `f`.
/// `out`: `[w/f, h/f, c]` (overwritten).
pub fn avg_pool_forward(x: &[f32], w: usize, h: usize, c: usize, f: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w * h * c);
    debug_assert_eq!(w % f, 0);
    debug_assert_eq!(h % f, 0);
    let (ow, oh) = (w / f, h / f);
    debug_assert_eq!(out.len(), ow * oh * c);
    let inv = 1.0f32 / (f * f) as f32;
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0.0f32;
                for dy in 0..f {
                    let row = (ci * h + oy * f + dy) * w + ox * f;
                    for dx in 0..f {
                        acc += x[row + dx];
                    }
                }
                out[(ci * oh + oy) * ow + ox] = acc * inv;
            }
        }
    }
}

/// VJP of [`avg_pool_forward`]: every cell of an `f×f` block receives
/// `dy/f²`. Accumulates **into** `dx` (`[w, h, c]`, input-sized).
pub fn avg_pool_input_grad(dy: &[f32], w: usize, h: usize, c: usize, f: usize, dx: &mut [f32]) {
    let (ow, oh) = (w / f, h / f);
    debug_assert_eq!(dy.len(), ow * oh * c);
    debug_assert_eq!(dx.len(), w * h * c);
    let inv = 1.0f32 / (f * f) as f32;
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let g = dy[(ci * oh + oy) * ow + ox] * inv;
                for by in 0..f {
                    let row = (ci * h + oy * f + by) * w + ox * f;
                    for bx in 0..f {
                        dx[row + bx] += g;
                    }
                }
            }
        }
    }
}

/// Factor-`f` nearest-neighbour upsampling per channel slab: every input
/// cell is replicated over an `f×f` output block. `out`: `[w·f, h·f, c]`
/// (overwritten).
pub fn upsample_forward(x: &[f32], w: usize, h: usize, c: usize, f: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w * h * c);
    let (ow, oh) = (w * f, h * f);
    debug_assert_eq!(out.len(), ow * oh * c);
    for ci in 0..c {
        for y in 0..h {
            for xx in 0..w {
                let v = x[(ci * h + y) * w + xx];
                for by in 0..f {
                    let row = (ci * oh + y * f + by) * ow + xx * f;
                    for bx in 0..f {
                        out[row + bx] = v;
                    }
                }
            }
        }
    }
}

/// VJP of [`upsample_forward`]: each input cell gathers the sum of its
/// `f×f` output block (exactly `f²·avg_pool` — upsample and avg-pool
/// are adjoint up to the mean weight). Accumulates **into** `dx`.
pub fn upsample_input_grad(dy: &[f32], w: usize, h: usize, c: usize, f: usize, dx: &mut [f32]) {
    let (ow, oh) = (w * f, h * f);
    debug_assert_eq!(dy.len(), ow * oh * c);
    debug_assert_eq!(dx.len(), w * h * c);
    for ci in 0..c {
        for y in 0..h {
            for xx in 0..w {
                let mut acc = 0.0f32;
                for by in 0..f {
                    let row = (ci * oh + y * f + by) * ow + xx * f;
                    for bx in 0..f {
                        acc += dy[row + bx];
                    }
                }
                dx[(ci * h + y) * w + xx] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        Rng::new(seed).fill_uniform(&mut v, lo, hi);
        v
    }

    fn dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn conv2d_matches_hand_computed_3x3() {
        // 1 channel, 3×3 image, identity-plus-shift kernel: every output
        // cell is hand-checkable including the zero-padded border
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]; // rows of 3
        // kernel reads x[y+ky−1, x+kx−1]; taps: center 1, east 2
        let mut wt = [0.0f32; 9];
        wt[4] = 1.0; // (ky=1, kx=1) center
        wt[5] = 2.0; // (ky=1, kx=2) reads the cell to the EAST
        let b = [0.5f32];
        let mut out = [0.0f32; 9];
        conv2d_forward(&x, &wt, &b, 3, 3, 1, 1, 3, &mut out);
        // out[y][x] = 0.5 + x[y][x] + 2·x[y][x+1] (0 past the border)
        let want = [
            0.5 + 1.0 + 4.0,
            0.5 + 2.0 + 6.0,
            0.5 + 3.0,
            0.5 + 4.0 + 10.0,
            0.5 + 5.0 + 12.0,
            0.5 + 6.0,
            0.5 + 7.0 + 16.0,
            0.5 + 8.0 + 18.0,
            0.5 + 9.0,
        ];
        assert_eq!(out, want);
    }

    #[test]
    fn conv2d_input_grad_is_the_exact_adjoint() {
        // <conv(x), dy> must equal <x, conv_input_grad(dy)> when bias = 0:
        // the input VJP is the transpose of the linear-in-x map
        let (w, h, cin, cout, k) = (5, 4, 2, 3, 3);
        let x = randv(1, w * h * cin, -1.0, 1.0);
        let wt = randv(2, k * k * cin * cout, -0.5, 0.5);
        let dy = randv(3, w * h * cout, -1.0, 1.0);
        let mut y = vec![0.0f32; w * h * cout];
        conv2d_forward(&x, &wt, &[0.0; 3], w, h, cin, cout, k, &mut y);
        let mut dx = vec![0.0f32; w * h * cin];
        conv2d_input_grad(&dy, &wt, w, h, cin, cout, k, &mut dx);
        let lhs = dot(&y, &dy);
        let rhs = dot(&x, &dx);
        assert!(
            (lhs - rhs).abs() <= 1e-4 * lhs.abs().max(rhs.abs()).max(1.0),
            "<Ax,dy>={lhs} vs <x,Aᵀdy>={rhs}"
        );
    }

    #[test]
    fn conv3d_reduces_to_conv2d_on_a_single_slab() {
        // nz = 1 with a k³ kernel whose only nonzero taps sit on the
        // central kz plane must reproduce conv2d with those taps
        let (w, h, cin, cout, k) = (4, 4, 2, 2, 3);
        let x = randv(7, w * h * cin, -1.0, 1.0);
        let w2 = randv(8, k * k * cin * cout, -0.5, 0.5);
        let b = randv(9, cout, -0.1, 0.1);
        let mut w3 = vec![0.0f32; k * k * k * cin * cout];
        for co in 0..cout {
            for ci in 0..cin {
                for t in 0..k * k {
                    // kz = 1 (center plane): tap index (1·k + ky)·k + kx
                    w3[(co * cin + ci) * k * k * k + k * k + t] =
                        w2[(co * cin + ci) * k * k + t];
                }
            }
        }
        let mut y2 = vec![0.0f32; w * h * cout];
        conv2d_forward(&x, &w2, &b, w, h, cin, cout, k, &mut y2);
        let mut y3 = vec![0.0f32; w * h * cout];
        conv3d_forward(&x, &w3, &b, w, h, 1, cin, cout, k, &mut y3);
        assert_eq!(y2, y3);
    }

    #[test]
    fn conv3d_input_grad_is_the_exact_adjoint() {
        let (w, h, nz, cin, cout, k) = (3, 4, 3, 2, 2, 3);
        let x = randv(11, w * h * nz * cin, -1.0, 1.0);
        let wt = randv(12, k * k * k * cin * cout, -0.5, 0.5);
        let dy = randv(13, w * h * nz * cout, -1.0, 1.0);
        let mut y = vec![0.0f32; w * h * nz * cout];
        conv3d_forward(&x, &wt, &[0.0; 2], w, h, nz, cin, cout, k, &mut y);
        let mut dx = vec![0.0f32; w * h * nz * cin];
        conv3d_input_grad(&dy, &wt, w, h, nz, cin, cout, k, &mut dx);
        let lhs = dot(&y, &dy);
        let rhs = dot(&x, &dx);
        assert!(
            (lhs - rhs).abs() <= 1e-4 * lhs.abs().max(rhs.abs()).max(1.0),
            "<Ax,dy>={lhs} vs <x,Aᵀdy>={rhs}"
        );
    }

    #[test]
    fn weight_and_bias_grads_match_finite_differences() {
        let (w, h, cin, cout, k) = (4, 3, 2, 2, 3);
        let x = randv(21, w * h * cin, -1.0, 1.0);
        let wt = randv(22, k * k * cin * cout, -0.5, 0.5);
        let b = randv(23, cout, -0.1, 0.1);
        let dy = randv(24, w * h * cout, -1.0, 1.0);
        // L(wt, b) = <conv(x; wt, b), dy>; dL/dwt and dL/db are the VJPs
        let f = |wt: &[f32], b: &[f32]| -> f64 {
            let mut y = vec![0.0f32; w * h * cout];
            conv2d_forward(&x, wt, b, w, h, cin, cout, k, &mut y);
            dot(&y, &dy)
        };
        let mut dw = vec![0.0f32; wt.len()];
        conv2d_weight_grad(&x, &dy, w, h, cin, cout, k, &mut dw);
        let mut db = vec![0.0f32; cout];
        conv2d_bias_grad(&dy, w, h, cout, &mut db);
        let eps = 1e-3f32;
        for i in 0..wt.len() {
            let mut wp = wt.clone();
            wp[i] += eps;
            let mut wm = wt.clone();
            wm[i] -= eps;
            let fd = (f(&wp, &b) - f(&wm, &b)) / (2.0 * eps as f64);
            assert!(
                (fd - dw[i] as f64).abs() <= 1e-3 * fd.abs().max(1.0),
                "dw[{i}]: fd {fd} vs vjp {}",
                dw[i]
            );
        }
        for i in 0..cout {
            let mut bp = b.clone();
            bp[i] += eps;
            let mut bm = b.clone();
            bm[i] -= eps;
            let fd = (f(&wt, &bp) - f(&wt, &bm)) / (2.0 * eps as f64);
            assert!(
                (fd - db[i] as f64).abs() <= 1e-3 * fd.abs().max(1.0),
                "db[{i}]: fd {fd} vs vjp {}",
                db[i]
            );
        }
    }

    #[test]
    fn pool_and_upsample_are_adjoint_up_to_the_mean_weight() {
        // <avg_pool(x), y> · f² = <x, upsample(y)>: pooling's VJP is
        // upsample/f², upsample's VJP is block-sum — one identity checks
        // all four kernels against each other
        let (w, h, c, f) = (6, 4, 3, 2);
        let x = randv(31, w * h * c, -1.0, 1.0);
        let y = randv(32, (w / f) * (h / f) * c, -1.0, 1.0);
        let mut px = vec![0.0f32; (w / f) * (h / f) * c];
        avg_pool_forward(&x, w, h, c, f, &mut px);
        let mut uy = vec![0.0f32; w * h * c];
        upsample_forward(&y, w / f, h / f, c, f, &mut uy);
        let lhs = dot(&px, &y) * (f * f) as f64;
        let rhs = dot(&x, &uy);
        assert!((lhs - rhs).abs() <= 1e-5 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        // and the VJP kernels agree with their forward counterparts
        let mut dx_pool = vec![0.0f32; w * h * c];
        avg_pool_input_grad(&y, w, h, c, f, &mut dx_pool);
        let want: Vec<f32> = uy.iter().map(|&v| v / (f * f) as f32).collect();
        assert_eq!(dx_pool, want, "pool VJP must equal upsample/f²");
        let mut dx_up = vec![0.0f32; (w / f) * (h / f) * c];
        upsample_input_grad(&x, w / f, h / f, c, f, &mut dx_up);
        let scaled: Vec<f32> = px.iter().map(|&v| v * (f * f) as f32).collect();
        // block-sum vs f²·block-mean: identical sums, but computed in a
        // different order/scale — compare within one ulp-ish tolerance
        for (a, b) in dx_up.iter().zip(scaled.iter()) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn conv_init_is_deterministic_and_bounded() {
        let a = conv_init(42, 9, 2, 4);
        let b = conv_init(42, 9, 2, 4);
        assert_eq!(a, b);
        let bound = (6.0 / 18.0f64).sqrt() as f32;
        assert!(a.iter().all(|v| v.abs() <= bound));
        assert!(a.iter().any(|v| *v != 0.0));
        assert_ne!(conv_init(43, 9, 2, 4), a);
    }
}
