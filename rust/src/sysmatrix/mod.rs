//! Precomputed sparse system-matrix baseline (CSR).
//!
//! The paper's introduction argues against this approach (Lahiri et al.
//! 2023): "this method utilizes an enormous amount of memory (even though
//! it is a sparse matrix) and is significantly inefficient because
//! fetching the system matrix values from memory is much slower than
//! computing these coefficients on the fly". We implement it faithfully —
//! CSR storage built from the *same* projector coefficients — so Table 1
//! can quantify both claims on identical numerics: the stored matrix
//! reproduces the on-the-fly results bit-for-bit while its memory grows as
//! O(nnz) instead of O(volume + projections).

use crate::array::{Sino, Vol3};
use crate::geometry::Geometry;
use crate::projector::{Model, Projector};

/// A CSR sparse matrix mapping volume (columns) to projections (rows).
#[derive(Clone, Debug)]
pub struct SystemMatrix {
    pub nrows: usize,
    pub ncols_mat: usize,
    pub row_ptr: Vec<u64>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
    /// Shape bookkeeping for the sinogram side.
    pub sino_shape: (usize, usize, usize),
    pub vol_shape: (usize, usize, usize),
}

impl SystemMatrix {
    /// Build the full matrix by enumerating the projector's coefficients:
    /// ray-by-ray for Siddon/Joseph, voxel-footprint scatter for SF.
    pub fn build(p: &Projector) -> SystemMatrix {
        match p.model {
            Model::Siddon | Model::Joseph => Self::build_ray_driven(p),
            Model::SF => Self::build_voxel_driven(p),
        }
    }

    fn build_ray_driven(p: &Projector) -> SystemMatrix {
        let nviews = p.geom.nviews();
        let nrows_det = p.geom.nrows();
        let ncols_det = p.geom.ncols();
        let nrays = nviews * nrows_det * ncols_det;
        let nvox = p.vg.num_voxels();
        let use_siddon = p.model == Model::Siddon;

        let mut row_ptr = Vec::with_capacity(nrays + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u64);
        for view in 0..nviews {
            for row in 0..nrows_det {
                for col in 0..ncols_det {
                    let ray = p.geom.ray(view, row, col);
                    if use_siddon {
                        crate::projector::siddon::walk_ray(&p.vg, &ray, |idx, w| {
                            col_idx.push(idx as u32);
                            values.push(w);
                        });
                    } else {
                        crate::projector::joseph::walk_ray(&p.vg, &ray, |idx, w| {
                            col_idx.push(idx as u32);
                            values.push(w);
                        });
                    }
                    row_ptr.push(col_idx.len() as u64);
                }
            }
        }
        SystemMatrix {
            nrows: nrays,
            ncols_mat: nvox,
            row_ptr,
            col_idx,
            values,
            sino_shape: (nviews, nrows_det, ncols_det),
            vol_shape: (p.vg.nx, p.vg.ny, p.vg.nz),
        }
    }

    fn build_voxel_driven(p: &Projector) -> SystemMatrix {
        // SF coefficients are enumerated voxel→bins per view; bucket them
        // per ray, then pack to CSR.
        let nviews = p.geom.nviews();
        let nrows_det = p.geom.nrows();
        let ncols_det = p.geom.ncols();
        let nrays = nviews * nrows_det * ncols_det;
        let nvox = p.vg.num_voxels();
        let mut buckets: Vec<Vec<(u32, f32)>> = vec![Vec::new(); nrays];
        for view in 0..nviews {
            let mut emit = |flat: usize, row: usize, col: usize, coeff: f64| {
                let ray_idx = (view * nrows_det + row) * ncols_det + col;
                buckets[ray_idx].push((flat as u32, coeff as f32));
            };
            match &p.geom {
                Geometry::Parallel(g) => {
                    crate::projector::sf::parallel_view_coeffs_pub(&p.vg, g, view, &mut emit)
                }
                Geometry::Fan(g) => crate::projector::sf::fan_view_coeffs_pub(
                    &p.vg,
                    g,
                    view,
                    &mut |flat, col, c| emit(flat, 0, col, c),
                ),
                Geometry::Cone(g) => {
                    crate::projector::sf::cone_view_coeffs_pub(&p.vg, g, view, &mut emit)
                }
                Geometry::Modular(_) => {
                    panic!("SF system matrix undefined for modular beams (DESIGN.md §3)")
                }
            }
        }
        let mut row_ptr = Vec::with_capacity(nrays + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u64);
        for b in buckets {
            for (c, v) in b {
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len() as u64);
        }
        SystemMatrix {
            nrows: nrays,
            ncols_mat: nvox,
            row_ptr,
            col_idx,
            values,
            sino_shape: (nviews, nrows_det, ncols_det),
            vol_shape: (p.vg.nx, p.vg.ny, p.vg.nz),
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Bytes held by the matrix itself — the Table-1 memory number for the
    /// baseline (row_ptr + col_idx + values).
    pub fn nbytes(&self) -> usize {
        self.row_ptr.len() * 8 + self.col_idx.len() * 4 + self.values.len() * 4
    }

    /// SpMV forward projection into a flat buffer: `y = A·x`
    /// (overwrites `y`).
    pub fn forward_into_slice(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.ncols_mat, "volume size mismatch");
        assert_eq!(y.len(), self.nrows, "sinogram size mismatch");
        for r in 0..self.nrows {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            let mut acc = 0.0f32;
            for k in lo..hi {
                acc += self.values[k] * x[self.col_idx[k] as usize];
            }
            y[r] = acc;
        }
    }

    /// Transpose SpMV backprojection into a flat buffer: `x = Aᵀ·y`
    /// (overwrites `x`) — matched by construction.
    pub fn back_into_slice(&self, y: &[f32], x: &mut [f32]) {
        assert_eq!(y.len(), self.nrows, "sinogram size mismatch");
        assert_eq!(x.len(), self.ncols_mat, "volume size mismatch");
        x.fill(0.0);
        for r in 0..self.nrows {
            let yv = y[r];
            if yv == 0.0 {
                continue;
            }
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            for k in lo..hi {
                x[self.col_idx[k] as usize] += self.values[k] * yv;
            }
        }
    }

    /// SpMV forward projection `y = A·x`.
    pub fn forward(&self, vol: &Vol3) -> Sino {
        let (nv, nr, nc) = self.sino_shape;
        let mut sino = Sino::zeros(nv, nr, nc);
        self.forward_into_slice(&vol.data, &mut sino.data);
        sino
    }

    /// Transpose SpMV backprojection `x = Aᵀ·y` — matched by construction.
    pub fn back(&self, sino: &Sino) -> Vol3 {
        let (nx, ny, nz) = self.vol_shape;
        let mut vol = Vol3::zeros(nx, ny, nz);
        self.back_into_slice(&sino.data, &mut vol.data);
        vol
    }
}

/// The stored-matrix baseline speaks the same operator language as the
/// on-the-fly projectors: every solver and combinator in [`crate::ops`]
/// runs against it unchanged, which is what lets the Table-1 comparison
/// hold the numerics fixed while swapping the execution strategy.
impl crate::ops::LinearOp for SystemMatrix {
    fn domain_shape(&self) -> crate::ops::Shape {
        let (nx, ny, nz) = self.vol_shape;
        crate::ops::Shape([nx, ny, nz])
    }

    fn range_shape(&self) -> crate::ops::Shape {
        let (nv, nr, nc) = self.sino_shape;
        crate::ops::Shape([nv, nr, nc])
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        self.forward_into_slice(x, y)
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        self.back_into_slice(y, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{ConeBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::util::rng::Rng;

    fn random_vol(p: &Projector, seed: u64) -> Vol3 {
        let mut rng = Rng::new(seed);
        let mut v = p.new_vol();
        rng.fill_uniform(&mut v.data, 0.0, 1.0);
        v
    }

    #[test]
    fn matches_on_the_fly_exactly_ray_driven() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 24, 1.0));
        for model in [Model::Siddon, Model::Joseph] {
            let p = Projector::new(g.clone(), vg.clone(), model).with_threads(1);
            let mat = SystemMatrix::build(&p);
            let x = random_vol(&p, 3);
            let direct = p.forward(&x);
            let via_mat = mat.forward(&x);
            for i in 0..direct.len() {
                assert!(
                    (direct.data[i] - via_mat.data[i]).abs() < 1e-5,
                    "{}: idx {i}",
                    model.name()
                );
            }
        }
    }

    #[test]
    fn matches_on_the_fly_sf() {
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(6, 18, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(1);
        let mat = SystemMatrix::build(&p);
        let x = random_vol(&p, 5);
        let direct = p.forward(&x);
        let via_mat = mat.forward(&x);
        for i in 0..direct.len() {
            assert!((direct.data[i] - via_mat.data[i]).abs() < 1e-4, "idx {i}");
        }
    }

    #[test]
    fn transpose_is_matched() {
        let vg = VolumeGeometry::cube(8, 1.0);
        let g = Geometry::Cone(ConeBeam::standard(5, 8, 10, 1.5, 1.5, 50.0, 100.0));
        let p = Projector::new(g, vg, Model::Joseph).with_threads(1);
        let mat = SystemMatrix::build(&p);
        let mut rng = Rng::new(7);
        let mut x = p.new_vol();
        let mut y = p.new_sino();
        rng.fill_uniform(&mut x.data, -1.0, 1.0);
        rng.fill_uniform(&mut y.data, -1.0, 1.0);
        let lhs = crate::util::dot_f64(&mat.forward(&x).data, &y.data);
        let rhs = crate::util::dot_f64(&x.data, &mat.back(&y).data);
        assert!((lhs - rhs).abs() / lhs.abs().max(1e-12) < 1e-5);
    }

    #[test]
    fn memory_exceeds_one_copy() {
        // the paper's motivation: matrix memory >> one volume + one sino
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(32, 48, 1.0));
        let p = Projector::new(g, vg, Model::Siddon).with_threads(1);
        let mat = SystemMatrix::build(&p);
        let one_copy = crate::metrics::one_copy_bytes(p.vg.num_voxels(), p.new_sino().len());
        assert!(
            mat.nbytes() > 3 * one_copy,
            "matrix {} vs one-copy {}",
            mat.nbytes(),
            one_copy
        );
    }

    #[test]
    fn nnz_matches_row_ptr() {
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(4, 12, 1.0));
        let p = Projector::new(g, vg, Model::Joseph).with_threads(1);
        let mat = SystemMatrix::build(&p);
        assert_eq!(mat.nnz() as u64, *mat.row_ptr.last().unwrap());
        assert_eq!(mat.row_ptr.len(), mat.nrows + 1);
    }
}
