//! Coordinator integration: the full serving stack (TCP server → batcher →
//! worker pool → native executor) on a real projection workload.

use std::sync::Arc;

use leap::coordinator::server::{Client, Server};
use leap::coordinator::{BatchPolicy, Coordinator, Executor, NativeExecutor, Router};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};

fn native_stack() -> (Arc<Coordinator>, VolumeGeometry, ParallelBeam) {
    let vg = VolumeGeometry::slice2d(32, 32, 1.0);
    let g = ParallelBeam::standard_2d(24, 48, 1.0);
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
    let router: Arc<dyn Executor> = Arc::new(Router::new(vec![Arc::new(NativeExecutor::new(p))]));
    let coord = Arc::new(Coordinator::new(router, BatchPolicy::default(), 1 << 28, 2));
    (coord, vg, g)
}

#[test]
fn native_fp_bp_roundtrip_over_tcp() {
    let (coord, vg, _g) = native_stack();
    let server = Server::start("127.0.0.1:0", coord).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();

    let phantom = shepp::shepp_logan_2d(14.0, 0.02);
    let truth = phantom.rasterize(&vg, 2);

    let reply = client.call("native_fp", &[&truth.data]).unwrap();
    let outputs = reply.get("outputs").unwrap().as_arr().unwrap();
    let sino: Vec<f32> =
        outputs[0].as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    assert_eq!(sino.len(), 24 * 48);
    assert!(sino.iter().cloned().fold(0.0f32, f32::max) > 0.1);

    let reply = client.call("native_fbp", &[&sino]).unwrap();
    let rec: Vec<f32> = reply.get("outputs").unwrap().as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let psnr = leap::metrics::psnr(&rec, &truth.data, None);
    assert!(psnr > 18.0, "served FBP psnr {psnr}");
}

#[test]
fn unknown_op_is_an_error_response() {
    let (coord, _, _) = native_stack();
    let server = Server::start("127.0.0.1:0", coord).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let reply = client.call("native_warp", &[&[1.0]]).unwrap();
    assert!(reply.get_str("error").unwrap().contains("no backend"));
}

#[test]
fn stats_reflect_served_load() {
    let (coord, vg, _) = native_stack();
    let server = Server::start("127.0.0.1:0", coord).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let vol = vec![0.01f32; vg.num_voxels()];
    for _ in 0..5 {
        client.call("native_fp", &[&vol]).unwrap();
    }
    let stats = client.stats().unwrap();
    let fp = stats.get("stats").unwrap().get("native_fp").unwrap();
    assert_eq!(fp.get_f64("count"), Some(5.0));
    assert_eq!(fp.get_f64("errors"), Some(0.0));
}

#[test]
fn concurrent_clients_throughput() {
    let (coord, vg, _) = native_stack();
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    let addr = server.addr;
    let nvox = vg.num_voxels();
    let mut handles = Vec::new();
    for t in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let vol = vec![0.005f32 * (t + 1) as f32; nvox];
            for _ in 0..8 {
                let r = client.call("native_fp", &[&vol]).unwrap();
                assert!(r.get("outputs").is_some(), "{r}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = coord.telemetry().snapshot();
    assert_eq!(snap["native_fp"].count, 24);
}
