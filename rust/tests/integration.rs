//! Cross-module integration tests: phantom → projector → reconstruction
//! quality, geometry config round-trips, system-matrix equivalence, and
//! the limited-angle data-consistency pipeline end-to-end (native path).

use leap::geometry::config::{scan_from_str, scan_to_string, ScanConfig};
use leap::geometry::{angles_deg, ConeBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::phantom::{luggage, shepp, Phantom, Shape};
use leap::projector::{Model, Projector};
use leap::recon;
use leap::sysmatrix::SystemMatrix;
use leap::{Sino, Vol3};

/// Simulate → FBP → SIRT at 64²: every projector model reconstructs the
/// Shepp-Logan phantom with reasonable fidelity, and SIRT beats FBP on
/// few-view data.
#[test]
fn phantom_to_recon_all_models() {
    let vg = VolumeGeometry::slice2d(64, 64, 1.0);
    let g = ParallelBeam::standard_2d(48, 96, 1.0);
    let ph = shepp::shepp_logan_2d(28.0, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let sino = ph.project(&Geometry::Parallel(g.clone()));

    let fbp = recon::fbp_parallel(&vg, &g, &sino, recon::Window::Hann, 1);
    let e_fbp = metrics::rmse(&fbp.data, &truth.data);

    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), model);
        let r = recon::sirt(
            &p,
            &sino,
            &p.new_vol(),
            &recon::SirtOpts { iterations: 40, ..Default::default() },
        );
        let e = metrics::rmse(&r.vol.data, &truth.data);
        assert!(
            e < e_fbp * 1.2,
            "{}: sirt rmse {e} vs fbp {e_fbp}",
            model.name()
        );
        let psnr = metrics::psnr(&r.vol.data, &truth.data, None);
        // analytic (continuous-phantom) data bounds PSNR by the grid's
        // discretization error here — ~24.6 dB for every model at 64²/48v
        assert!(psnr > 23.0, "{}: psnr {psnr}", model.name());
    }
}

/// The full scan config JSON round-trips through the parser and produces
/// identical projections.
#[test]
fn scan_config_roundtrip_projections() {
    let cfg = ScanConfig {
        geometry: Geometry::Cone(ConeBeam::standard(10, 12, 16, 1.3, 1.1, 90.0, 190.0)),
        volume: VolumeGeometry::cube(12, 1.2),
    };
    let text = scan_to_string(&cfg);
    let cfg2 = scan_from_str(&text).unwrap();
    let ph = Phantom::new(vec![Shape::Ellipsoid {
        center: [1.0, -2.0, 0.5],
        axes: [4.0, 5.0, 3.0],
        phi: 0.4,
        density: 0.05,
    }]);
    let a = ph.project(&cfg.geometry);
    let b = ph.project(&cfg2.geometry);
    assert_eq!(a.data, b.data);
}

/// The stored system matrix reproduces the on-the-fly projector exactly
/// while using far more memory — the Table-1 motivation at test scale.
#[test]
fn sysmatrix_equivalence_and_memory_blowup() {
    let vg = VolumeGeometry::slice2d(24, 24, 1.0);
    let g = Geometry::Parallel(ParallelBeam::standard_2d(18, 36, 1.0));
    let p = Projector::new(g, vg.clone(), Model::SF).with_threads(1);
    let mat = SystemMatrix::build(&p);
    let ph = shepp::shepp_logan_2d(10.0, 0.02);
    let vol = ph.rasterize(&vg, 2);
    let direct = p.forward(&vol);
    let via = mat.forward(&vol);
    for i in 0..direct.len() {
        assert!((direct.data[i] - via.data[i]).abs() < 1e-4);
    }
    let one_copy = metrics::one_copy_bytes(vg.num_voxels(), direct.len());
    assert!(mat.nbytes() > 2 * one_copy, "{} vs {}", mat.nbytes(), one_copy);
}

/// Limited-angle DC pipeline on a bag (the Figure-3 experiment in
/// miniature): refinement must improve both PSNR and SSIM.
#[test]
fn limited_angle_dc_pipeline_improves_metrics() {
    let n = 64;
    let voxel = 512.0 / n as f64;
    let vg = VolumeGeometry::slice2d(n, n, voxel);
    let nviews = 60;
    let keep = 20; // 60° of 180°
    let g = ParallelBeam::standard_2d(nviews, 96, voxel);
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);

    let bag = luggage::bag(3, &luggage::LuggageParams::default());
    let truth = bag.rasterize(&vg, 2);
    let y = bag.project(&Geometry::Parallel(g.clone()));
    let mask = recon::ViewMask::contiguous(nviews, 0, keep);
    let mut y_masked = y.clone();
    mask.apply(&mut y_masked);

    let g_lim = ParallelBeam { angles: g.angles[0..keep].to_vec(), ..g.clone() };
    let sino_lim = Sino::from_vec(keep, 1, g.ncols, y.data[..keep * g.ncols].to_vec());
    let mut pred = recon::fbp_parallel(&vg, &g_lim, &sino_lim, recon::Window::Hann, 1);
    leap::recon::fista_tv::tv_prox_vol(&mut pred, 2e-4, 15);
    for v in pred.data.iter_mut() {
        *v = v.max(0.0);
    }

    let refined = recon::refine(
        &p,
        &y_masked,
        &mask,
        &pred,
        &recon::DcOpts { iterations: 30, ..Default::default() },
    );
    let psnr_pred = metrics::psnr(&pred.data, &truth.data, None);
    let psnr_ref = metrics::psnr(&refined.data, &truth.data, None);
    let ssim_pred = metrics::ssim_vol(&pred, &truth, None);
    let ssim_ref = metrics::ssim_vol(&refined, &truth, None);
    assert!(psnr_ref > psnr_pred, "PSNR {psnr_pred} → {psnr_ref}");
    assert!(ssim_ref > ssim_pred, "SSIM {ssim_pred} → {ssim_ref}");
}

/// Sinogram completion: completed data has lower full-arc residual vs the
/// ground-truth sinogram than zero-filled data.
#[test]
fn sinogram_completion_reduces_residual() {
    let vg = VolumeGeometry::slice2d(32, 32, 1.0);
    let nviews = 30;
    let g = ParallelBeam::standard_2d(nviews, 48, 1.0);
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
    let ph = shepp::shepp_logan_2d(14.0, 0.02);
    let truth_sino = ph.project(&Geometry::Parallel(g.clone()));
    let mask = recon::ViewMask::contiguous(nviews, 0, 10);
    let mut masked = truth_sino.clone();
    mask.apply(&mut masked);
    // prior: rough SIRT recon from measured views only
    let prior = recon::sirt(
        &p,
        &masked,
        &p.new_vol(),
        &recon::SirtOpts {
            iterations: 20,
            view_mask: Some(mask.weights.clone()),
            ..Default::default()
        },
    )
    .vol;
    let completed = recon::complete_sinogram(&p, &masked, &mask, &prior);
    let e_zero = metrics::rmse(&masked.data, &truth_sino.data);
    let e_completed = metrics::rmse(&completed.data, &truth_sino.data);
    assert!(e_completed < e_zero, "completion {e_completed} vs zero-fill {e_zero}");
}

/// Matched pairs stay stable over very many iterations while the
/// unmatched (pixel-driven) backprojector drifts — the §2.1 claim.
#[test]
fn matched_pair_stable_unmatched_drifts() {
    let vg = VolumeGeometry::slice2d(24, 24, 1.0);
    let g = ParallelBeam::standard_2d(30, 36, 1.0);
    let geo = Geometry::Parallel(g.clone());
    let p = Projector::new(geo.clone(), vg.clone(), Model::SF);
    let ph = shepp::shepp_logan_2d(10.0, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let y = p.forward(&truth);

    // matched SIRT: long-run residual keeps decreasing (or stays flat)
    let long = recon::sirt(
        &p,
        &y,
        &p.new_vol(),
        &recon::SirtOpts { iterations: 400, track_residual: true, ..Default::default() },
    );
    let r = &long.residuals;
    assert!(r[399] <= r[50], "matched residual rose: {} → {}", r[50], r[399]);

    // unmatched iteration: replace Aᵀ with the pixel-driven backprojector
    // inside the same Landweber-style update; it must do *worse*
    let row_sum = p.forward_ones();
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let bp_ones = recon::fbp::backproject_pixel_parallel(&vg, &g, &{
        let mut s = p.new_sino();
        s.fill(1.0);
        s
    }, 1.0, 1);
    let inv_col: Vec<f32> =
        bp_ones.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut x = p.new_vol();
    let mut unmatched_final = f64::NAN;
    for it in 0..400 {
        let mut ax = p.forward(&x);
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        let grad = recon::fbp::backproject_pixel_parallel(&vg, &g, &ax, 1.0, 1);
        for i in 0..x.len() {
            x.data[i] = (x.data[i] + grad.data[i] * inv_col[i]).max(0.0);
        }
        if it == 399 {
            let ax2 = p.forward(&x);
            let res: f64 = ax2
                .data
                .iter()
                .zip(y.data.iter())
                .map(|(&a, &b)| {
                    let d = (a - b) as f64;
                    d * d
                })
                .sum::<f64>()
                .sqrt();
            unmatched_final = res;
        }
    }
    // normalized comparison of final data residuals
    let matched_final = {
        let ax = p.forward(&long.vol);
        ax.data
            .iter()
            .zip(y.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    };
    assert!(
        matched_final < unmatched_final,
        "matched {matched_final} should beat unmatched {unmatched_final}"
    );
}

/// Few-view (strided) masks behave like the paper's few-view CT setting.
#[test]
fn few_view_mask_recon() {
    let vg = VolumeGeometry::slice2d(32, 32, 1.0);
    let nviews = 40;
    let g = ParallelBeam::standard_2d(nviews, 48, 1.0);
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::Joseph);
    let ph = shepp::shepp_logan_2d(14.0, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let y = p.forward(&truth);
    let mask = recon::ViewMask::strided(nviews, 4); // 10 of 40 views
    let r = recon::sirt(
        &p,
        &y,
        &p.new_vol(),
        &recon::SirtOpts {
            iterations: 60,
            view_mask: Some(mask.weights.clone()),
            ..Default::default()
        },
    );
    let psnr = metrics::psnr(&r.vol.data, &truth.data, None);
    assert!(psnr > 22.0, "few-view psnr {psnr}");
}

/// Non-equispaced angles (paper: "non-equispaced projection angles") work
/// through the whole stack.
#[test]
fn non_equispaced_angles() {
    let vg = VolumeGeometry::slice2d(24, 24, 1.0);
    let mut angles = angles_deg(20, 0.0, 180.0);
    // jitter deterministically
    for (i, a) in angles.iter_mut().enumerate() {
        *a += ((i * 2654435761) % 100) as f64 / 100.0 * 0.01;
    }
    let g = ParallelBeam { nrows: 1, ncols: 36, du: 1.0, dv: 1.0, cu: 0.0, cv: 0.0, angles };
    let p = Projector::new(Geometry::Parallel(g), vg.clone(), Model::SF);
    let ph = shepp::shepp_logan_2d(10.0, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let y = p.forward(&truth);
    let r = leap::recon::cgls::cgls(&p, &y, 30);
    let e = metrics::rmse(&r.vol.data, &truth.data);
    assert!(e < 2e-3, "rmse {e}");
}

/// Detector shifts (paper: "arbitrary 3D detector shifts") round-trip:
/// shifting the detector and the volume center together is an identity.
#[test]
fn detector_shift_consistency() {
    let ph = Phantom::new(vec![Shape::ellipse2d(3.0, -2.0, 8.0, 6.0, 0.3, 0.05)]);
    let base = ParallelBeam::standard_2d(12, 64, 1.0);
    let shifted = ParallelBeam { cu: 4.0, ..base.clone() };
    let a = ph.project(&Geometry::Parallel(base));
    let b = ph.project(&Geometry::Parallel(shifted));
    // shifting detector by k bins shifts the sinogram by k columns
    for view in 0..12 {
        for col in 6..58 {
            let x = a.at(view, 0, col);
            let y = b.at(view, 0, col - 4);
            assert!((x - y).abs() < 1e-5, "view {view} col {col}: {x} vs {y}");
        }
    }
}

/// §2.1 accuracy regression: against the bin-integrated projection of a
/// voxel-aligned object (where rasterization is exact), SF must beat the
/// point-sampling models by a wide margin.
#[test]
fn sf_most_accurate_on_voxel_aligned_object() {
    let vg = VolumeGeometry::slice2d(32, 32, 2.0);
    let ph = Phantom::new(vec![
        Shape::rect2d(0.0, 0.0, 12.0, 8.0, 0.0, 0.02),
        Shape::rect2d(-10.0, 6.0, 4.0, 6.0, 0.0, 0.015),
    ]);
    let vol = ph.rasterize(&vg, 4);
    let g = Geometry::Parallel(ParallelBeam::standard_2d(20, 48, 2.0));
    let reference = ph.project_binned(&g, 16);
    let mut errs = std::collections::HashMap::new();
    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(g.clone(), vg.clone(), model);
        let fp = p.forward(&vol);
        errs.insert(model.name(), leap::util::rel_l2(&fp.data, &reference.data, 1e-12));
    }
    assert!(errs["sf"] < 0.2 * errs["joseph"], "{errs:?}");
    assert!(errs["sf"] < 0.2 * errs["siddon"], "{errs:?}");
    assert!(errs["sf"] < 1e-3, "{errs:?}");
}

/// Large random scan configs exercise the projector without panics and
/// with finite outputs (hand-rolled property test).
#[test]
fn property_random_scans_finite() {
    let mut rng = leap::util::rng::Rng::new(2024);
    for trial in 0..10 {
        let n = 8 + rng.below(16);
        let vg = VolumeGeometry::slice2d(n, n, 0.5 + rng.f64());
        let nviews = 1 + rng.below(12);
        let ncols = n + rng.below(20);
        let g = match rng.below(3) {
            0 => Geometry::Parallel(ParallelBeam::standard_2d(nviews, ncols, 0.5 + rng.f64())),
            1 => Geometry::Fan(leap::geometry::FanBeam::standard(
                nviews,
                ncols,
                0.5 + rng.f64(),
                40.0 + rng.range(0.0, 40.0),
                120.0 + rng.range(0.0, 60.0),
            )),
            _ => Geometry::Cone(ConeBeam::standard(
                nviews,
                4,
                ncols,
                0.5 + rng.f64(),
                0.5 + rng.f64(),
                40.0 + rng.range(0.0, 40.0),
                120.0 + rng.range(0.0, 60.0),
            )),
        };
        let vg = if matches!(g, Geometry::Cone(_)) {
            VolumeGeometry { nz: 4, ..vg }
        } else {
            vg
        };
        let model = [Model::Siddon, Model::Joseph, Model::SF][rng.below(3)];
        let p = Projector::new(g, vg.clone(), model);
        let mut x = p.new_vol();
        rng.fill_uniform(&mut x.data, 0.0, 0.1);
        let sino = p.forward(&x);
        assert!(sino.data.iter().all(|v| v.is_finite()), "trial {trial}");
        let back: Vol3 = p.back(&sino);
        assert!(back.data.iter().all(|v| v.is_finite()), "trial {trial}");
    }
}
