//! Runtime integration: the AOT artifacts (JAX/Pallas → HLO → PJRT)
//! agree numerically with the native Rust projectors — the cross-language
//! correctness proof that the three layers implement one model.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use leap::geometry::{angles_deg, Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::runtime::Engine;
use leap::util::rel_l2;

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime integration: {err:#}");
            None
        }
    }
}

/// Build the native projector matching the artifact spec.
fn native_for(engine: &Engine, model: Model) -> (Projector, VolumeGeometry) {
    let spec = &engine.spec;
    let vg = VolumeGeometry::slice2d(spec.n, spec.n, spec.voxel);
    let g = ParallelBeam {
        nrows: 1,
        ncols: spec.ncols,
        du: spec.du,
        dv: spec.du,
        cu: 0.0,
        cv: 0.0,
        angles: angles_deg(spec.nviews, 0.0, spec.arc_deg),
    };
    (Projector::new(Geometry::Parallel(g), vg.clone(), model), vg)
}

#[test]
fn artifact_fp_matches_native_sf() {
    let Some(engine) = engine() else { return };
    let (p, vg) = native_for(&engine, Model::SF);
    let ph = shepp::shepp_logan_2d(0.4 * vg.nx as f64 * vg.vx, 0.02);
    let vol = ph.rasterize(&vg, 2);
    let native = p.forward(&vol);
    let artifact = engine.run1("fp_sf", &[&vol.data]).unwrap();
    let err = rel_l2(&artifact, &native.data, 1e-12);
    assert!(err < 1e-4, "artifact vs native SF forward: rel {err}");
}

#[test]
fn artifact_fp_matches_native_joseph() {
    let Some(engine) = engine() else { return };
    let (p, vg) = native_for(&engine, Model::Joseph);
    let ph = shepp::shepp_logan_2d(0.4 * vg.nx as f64 * vg.vx, 0.02);
    let vol = ph.rasterize(&vg, 2);
    let native = p.forward(&vol);
    let artifact = engine.run1("fp_joseph", &[&vol.data]).unwrap();
    let err = rel_l2(&artifact, &native.data, 1e-12);
    assert!(err < 1e-4, "artifact vs native joseph forward: rel {err}");
}

#[test]
fn artifact_bp_matches_native() {
    let Some(engine) = engine() else { return };
    let (p, _vg) = native_for(&engine, Model::SF);
    let mut rng = leap::util::rng::Rng::new(5);
    let mut sino = p.new_sino();
    rng.fill_uniform(&mut sino.data, 0.0, 1.0);
    let native = p.back(&sino);
    let artifact = engine.run1("bp_sf", &[&sino.data]).unwrap();
    let err = rel_l2(&artifact, &native.data, 1e-12);
    assert!(err < 1e-4, "artifact vs native SF back: rel {err}");
}

#[test]
fn artifact_adjoint_identity() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec.clone();
    let mut rng = leap::util::rng::Rng::new(9);
    let mut x = vec![0.0f32; spec.n * spec.n];
    let mut y = vec![0.0f32; spec.nviews * spec.ncols];
    rng.fill_uniform(&mut x, -1.0, 1.0);
    rng.fill_uniform(&mut y, -1.0, 1.0);
    let ax = engine.run1("fp_sf", &[&x]).unwrap();
    let aty = engine.run1("bp_sf", &[&y]).unwrap();
    let lhs = leap::util::dot_f64(&ax, &y);
    let rhs = leap::util::dot_f64(&x, &aty);
    let gap = (lhs - rhs).abs() / lhs.abs().max(1e-12);
    assert!(gap < 1e-4, "artifact adjoint gap {gap}");
}

#[test]
fn artifact_fbp_reconstructs() {
    let Some(engine) = engine() else { return };
    let (_, vg) = native_for(&engine, Model::SF);
    let ph = shepp::shepp_logan_2d(0.35 * vg.nx as f64 * vg.vx, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let sino = engine.run1("fp_sf", &[&truth.data]).unwrap();
    let rec = engine.run1("fbp", &[&sino]).unwrap();
    let psnr = metrics::psnr(&rec, &truth.data, None);
    assert!(psnr > 24.0, "artifact FBP psnr {psnr}");
}

#[test]
fn artifact_dc_refine_improves_prior() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec.clone();
    let (_, vg) = native_for(&engine, Model::SF);
    let ph = shepp::shepp_logan_2d(0.35 * vg.nx as f64 * vg.vx, 0.02);
    let truth = ph.rasterize(&vg, 2);
    let y = engine.run1("fp_sf", &[&truth.data]).unwrap();
    let keep = spec.nviews / 3;
    let mask: Vec<f32> = (0..spec.nviews).map(|v| if v < keep { 1.0 } else { 0.0 }).collect();
    // imperfect prior
    let pred: Vec<f32> = truth.data.iter().map(|&v| v * 0.85).collect();
    let refined = engine.run1("dc_refine", &[&pred, &y, &mask]).unwrap();
    let psnr_pred = metrics::psnr(&pred, &truth.data, None);
    let psnr_ref = metrics::psnr(&refined, &truth.data, None);
    assert!(psnr_ref > psnr_pred + 0.5, "dc_refine: {psnr_pred} → {psnr_ref}");
}

#[test]
fn artifact_dc_loss_grad_matches_native_residual() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec.clone();
    let (p, vg) = native_for(&engine, Model::SF);
    let mut rng = leap::util::rng::Rng::new(3);
    let mut x = vec![0.0f32; vg.nx * vg.ny];
    let mut y = vec![0.0f32; spec.nviews * spec.ncols];
    rng.fill_uniform(&mut x, 0.0, 0.05);
    rng.fill_uniform(&mut y, 0.0, 1.0);
    let mask = vec![1.0f32; spec.nviews];
    let out = engine.run("dc_loss_grad", &[&x, &y, &mask]).unwrap();
    assert_eq!(out.len(), 2, "value + grad");
    let loss = out[0][0] as f64;
    // native: ½‖Ax−y‖²
    let vol = leap::Vol3::from_vec(vg.nx, vg.ny, 1, x.clone());
    let ax = p.forward(&vol);
    let native_loss: f64 = ax
        .data
        .iter()
        .zip(y.iter())
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            0.5 * d * d
        })
        .sum();
    let rel = (loss - native_loss).abs() / native_loss.max(1e-12);
    assert!(rel < 1e-3, "loss {loss} vs native {native_loss}");
    // grad = Aᵀ(Ax−y)
    let mut resid = ax.clone();
    for i in 0..resid.len() {
        resid.data[i] -= y[i];
    }
    let native_grad = p.back(&resid);
    let err = rel_l2(&out[1], &native_grad.data, 1e-12);
    assert!(err < 1e-3, "grad rel err {err}");
}

#[test]
fn coordinator_serves_artifacts_end_to_end() {
    let Some(_) = engine() else { return };
    use leap::coordinator::{BatchPolicy, Coordinator, Executor, Request, Router};
    use std::sync::Arc;
    let host = leap::runtime::EngineHost::load("artifacts").unwrap();
    let n = host.spec.n;
    let router: Arc<dyn Executor> = Arc::new(Router::new(vec![Arc::new(host)]));
    let coord = Coordinator::new(router, BatchPolicy::default(), 1 << 30, 2);
    let vol = vec![0.01f32; n * n];
    let resp = coord.call(Request::new(1, "fp_sf", vec![vol]));
    assert!(resp.ok(), "{:?}", resp.error);
    assert!(resp.outputs[0].iter().any(|&v| v > 0.0));
}
