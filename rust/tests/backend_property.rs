//! Property tests for the pluggable compute backends (`leap::backend`).
//!
//! The backend contract has two tiers of agreement (docs/BACKENDS.md):
//!
//! * **Within** a backend, results are bit-identical across thread
//!   counts — the PR 2 slab-ownership invariant, extended per tier.
//! * **Across** backends, forward and back projections agree to a
//!   relative-l2 tolerance: the SIMD tier re-associates some multi-lane
//!   accumulations (cone backprojection, Joseph/Siddon ray marching),
//!   which is float-sum reordering, not a different discretization.
//!
//! Both properties are swept over every model × every geometry family,
//! plus the adjoint identity per backend and the validation story for
//! the non-executing PJRT slot.

use leap::backend::BackendKind;
use leap::geometry::config::ScanConfig;
use leap::geometry::{
    ConeBeam, DetectorShape, FanBeam, Geometry, HelicalCone, ModularBeam, ParallelBeam,
    VolumeGeometry,
};
use leap::projector::{Model, Projector};
use leap::util::{dot_f64, rng::Rng};
use leap::{LeapError, ScanBuilder};

/// One geometry per family (flat and curved cone detectors both count:
/// they take different footprint/ray code paths), plus a helical
/// trajectory served through its modular-beam export — helical is a
/// first-class planned geometry and sweeps every backend property.
fn all_geometries() -> Vec<Geometry> {
    let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
    let mut curved = cone.clone();
    curved.shape = DetectorShape::Curved;
    let helix = HelicalCone::standard(1.5, 8, 6, 10, 1.5, 1.5, 50.0, 100.0, 8.0);
    vec![
        Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
        Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
        Geometry::Modular(helix.to_modular()),
    ]
}

fn vg_for(geom: &Geometry) -> VolumeGeometry {
    if matches!(geom, Geometry::Fan(_)) {
        VolumeGeometry::slice2d(12, 12, 1.0)
    } else {
        VolumeGeometry::cube(10, 1.0)
    }
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - y as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

const EXECUTABLE: [BackendKind; 2] = [BackendKind::Scalar, BackendKind::Simd];

/// Re-associating lane partials perturbs sums by a few ulps per term;
/// 1e-5 relative l2 is ~100× looser than observed and ~100× tighter
/// than any discretization difference would produce.
const CROSS_BACKEND_TOL: f64 = 1e-5;

#[test]
fn backends_agree_within_tolerance_all_models_all_geometries() {
    let mut rng = Rng::new(701);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let scalar = Projector::new(geom.clone(), vg.clone(), model)
                .with_threads(3)
                .with_backend(BackendKind::Scalar);
            let simd = Projector::new(geom.clone(), vg.clone(), model)
                .with_threads(3)
                .with_backend(BackendKind::Simd);
            let mut x = scalar.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            let fwd_gap = rel_l2(&simd.forward(&x).data, &scalar.forward(&x).data);
            assert!(
                fwd_gap <= CROSS_BACKEND_TOL,
                "{}/{}: forward cross-backend gap {fwd_gap}",
                model.name(),
                scalar.geom.kind()
            );
            let mut y = scalar.new_sino();
            rng.fill_uniform(&mut y.data, -1.0, 1.0);
            let back_gap = rel_l2(&simd.back(&y).data, &scalar.back(&y).data);
            assert!(
                back_gap <= CROSS_BACKEND_TOL,
                "{}/{}: back cross-backend gap {back_gap}",
                model.name(),
                scalar.geom.kind()
            );
        }
    }
}

#[test]
fn each_backend_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(702);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for kind in EXECUTABLE {
                let single = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(1)
                    .with_backend(kind);
                let multi = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(3)
                    .with_backend(kind);
                let mut x = single.new_vol();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                assert_eq!(
                    single.forward(&x).data,
                    multi.forward(&x).data,
                    "{}/{}/{}: forward depends on thread count",
                    kind.name(),
                    model.name(),
                    single.geom.kind()
                );
                let mut y = single.new_sino();
                rng.fill_uniform(&mut y.data, -1.0, 1.0);
                assert_eq!(
                    single.back(&y).data,
                    multi.back(&y).data,
                    "{}/{}/{}: back depends on thread count",
                    kind.name(),
                    model.name(),
                    single.geom.kind()
                );
            }
        }
    }
}

#[test]
fn adjoint_identity_holds_per_backend() {
    let mut rng = Rng::new(703);
    // exact only on the f32 storage tier: a reduced tier's Aᵀ reads a
    // quantized sinogram, so when the process default (LEAP_STORAGE —
    // the CI matrix axis) is 16-bit the identity holds to the tier's
    // accuracy class instead (docs/MEMORY.md)
    let tol = if leap::precision::default_tier() == leap::StorageTier::F32 { 5e-5 } else { 5e-3 };
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for kind in EXECUTABLE {
                let p = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(2)
                    .with_backend(kind);
                let mut x = p.new_vol();
                let mut y = p.new_sino();
                rng.fill_uniform(&mut x.data, -1.0, 1.0);
                rng.fill_uniform(&mut y.data, -1.0, 1.0);
                let ax = p.forward(&x);
                let aty = p.back(&y);
                let lhs = dot_f64(&ax.data, &y.data);
                let rhs = dot_f64(&x.data, &aty.data);
                let gap = (lhs - rhs).abs() / lhs.abs().max(rhs.abs()).max(1e-12);
                assert!(
                    gap < tol,
                    "{}/{}/{}: adjoint gap {gap}",
                    kind.name(),
                    model.name(),
                    p.geom.kind()
                );
            }
        }
    }
}

#[test]
fn planned_and_direct_paths_agree_per_backend() {
    // the plan/execute-split invariant (PR 1) must survive backend
    // selection: a lowered plan and a direct projector on the same tier
    // produce the same bits
    let mut rng = Rng::new(704);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for kind in EXECUTABLE {
            let p = Projector::new(geom.clone(), vg.clone(), Model::SF)
                .with_threads(3)
                .with_backend(kind);
            let plan = p.plan();
            assert_eq!(plan.backend(), kind, "plan must snapshot its projector's backend");
            let mut x = p.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            let direct = p.forward(&x);
            let mut planned = p.new_sino();
            p.forward_with_plan(&plan, &x, &mut planned);
            assert_eq!(
                direct.data,
                planned.data,
                "{}/{}: planned forward differs from direct",
                kind.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn lowering_rebinds_a_plan_without_replanning_semantics() {
    let vg = VolumeGeometry::cube(8, 1.0);
    let g = Geometry::Cone(ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0));
    let p = Projector::new(g.clone(), vg.clone(), Model::SF)
        .with_threads(2)
        .with_backend(BackendKind::Scalar);
    let plan = p.plan();
    let lowered = plan.lower(BackendKind::Simd).unwrap();
    assert_eq!(lowered.backend(), BackendKind::Simd);
    assert_eq!(plan.backend(), BackendKind::Scalar, "lowering must not mutate the source plan");
    // a lowered plan equals a plan built natively on the target tier
    let native = Projector::new(g, vg, Model::SF)
        .with_threads(2)
        .with_backend(BackendKind::Simd)
        .plan();
    let mut x = p.new_vol();
    Rng::new(705).fill_uniform(&mut x.data, 0.0, 1.0);
    assert_eq!(lowered.forward(&x).data, native.forward(&x).data);
    // the non-executing slot cannot be lowered to
    let e = plan.lower(BackendKind::Pjrt).unwrap_err();
    assert!(matches!(e, LeapError::Unsupported(ref m) if m.contains("pjrt")), "{e:?}");
}

#[test]
fn builder_validates_backend_selection_end_to_end() {
    let cfg = ScanConfig {
        geometry: Geometry::Parallel(ParallelBeam::standard_2d(8, 16, 1.0)),
        volume: VolumeGeometry::slice2d(12, 12, 1.0),
    };
    for kind in EXECUTABLE {
        let scan =
            ScanBuilder::from_config(&cfg).model(Model::SF).threads(2).backend(kind).build().unwrap();
        assert_eq!(scan.backend(), kind);
    }
    // unknown names are a typed InvalidArgument at build time
    let e = ScanBuilder::from_config(&cfg).backend_str("warp").build().unwrap_err();
    assert!(matches!(e, LeapError::InvalidArgument(ref m) if m.contains("warp")), "{e:?}");
    // the pjrt slot is registered but capability-gated
    for attempt in [
        ScanBuilder::from_config(&cfg).backend(BackendKind::Pjrt).build(),
        ScanBuilder::from_config(&cfg).backend_str("pjrt").build(),
    ] {
        let e = attempt.unwrap_err();
        assert!(matches!(e, LeapError::Unsupported(ref m) if m.contains("pjrt")), "{e:?}");
    }
}

#[test]
fn solvers_agree_across_backends_within_tolerance() {
    // end-to-end: an iterative reconstruction run entirely on the SIMD
    // tier lands within tolerance of the scalar tier (errors do not
    // amplify across iterations — the operators stay matched per tier)
    let cfg = ScanConfig {
        geometry: Geometry::Parallel(ParallelBeam::standard_2d(16, 36, 1.0)),
        volume: VolumeGeometry::slice2d(24, 24, 1.0),
    };
    let truth = leap::phantom::shepp::shepp_logan_2d(10.0, 0.02).rasterize(&cfg.volume, 2);
    let mut recon = Vec::new();
    for kind in EXECUTABLE {
        let scan = ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .backend(kind)
            .build()
            .unwrap();
        let sino = scan.forward(&truth.data).unwrap();
        let solver = leap::Solver::Sirt { iterations: 8, lambda: 1.0, nonneg: true };
        recon.push(scan.solve(solver, &sino).unwrap());
    }
    let gap = rel_l2(&recon[1], &recon[0]);
    assert!(gap <= CROSS_BACKEND_TOL, "SIRT cross-backend gap {gap}");
}
