//! Integration/property tests for `leap::cluster` — the multi-process
//! sharded execution plane (docs/CLUSTER.md).
//!
//! The headline contract: a [`ShardedOp`] application is
//! **bit-identical to in-process execution at every worker count**,
//! including zero (the pure in-process fallback) and across worker
//! deaths mid-request. Workers here are hosted on threads inside the
//! test process — `run_worker_with` only needs a socket address, so a
//! thread is behaviourally the same as the `leap worker` process the
//! CLI spawns (the process form is exercised by
//! `examples/serve_client.rs --workers N` in CI) — plus hand-rolled
//! "fake" workers that speak just enough of the shard protocol to
//! misbehave deterministically: vanish with a shard in flight, reply
//! with Error frames, or register and then go silent.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use leap::cluster::{
    run_worker_with, ShardPlanner, ShardServer, ShardServerOptions, ShardedOp, WorkerOptions,
};
use leap::coordinator::wire::{read_frame, write_frame, write_frame_parts, Frame, FrameKind};
use leap::geometry::{ConeBeam, Geometry, VolumeGeometry};
use leap::projector::{Model, Projector};
use leap::util::json::Json;
use leap::util::rng::Rng;
use leap::LeapError;

/// Short timeouts so the failure paths run in milliseconds, but with
/// enough slack that a loaded CI box never trips them spuriously.
fn fast_opts() -> ShardServerOptions {
    ShardServerOptions {
        heartbeat_timeout: Duration::from_millis(800),
        task_deadline: Duration::from_secs(10),
        max_retries: 2,
    }
}

/// Host `n` real workers on threads, dialing `addr`. They exit cleanly
/// when the shard server drops (EOF on the channel).
fn spawn_workers(addr: &str, n: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let opts = WorkerOptions {
                    heartbeat_period: Duration::from_millis(200),
                    threads: None,
                    connect_retries: 50,
                };
                let _ = run_worker_with(&addr, opts);
            })
        })
        .collect()
}

fn wait_for_workers(server: &ShardServer, n: usize) {
    let t0 = Instant::now();
    while server.workers() < n {
        assert!(t0.elapsed() < Duration::from_secs(10), "workers failed to register in time");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn test_plan() -> Arc<leap::projector::ProjectionPlan> {
    let vg = VolumeGeometry::cube(10, 1.0);
    let g = Geometry::Cone(ConeBeam::standard(6, 8, 10, 1.5, 1.5, 60.0, 120.0));
    Arc::new(Projector::new(g, vg, Model::SF).with_threads(2).plan())
}

#[test]
fn shard_plan_depends_only_on_the_unit_count() {
    for units in [0, 1, 2, 7, 8, 9, 100] {
        let ranges = ShardPlanner::shard_ranges(units);
        // pure function: calling again gives the same plan
        assert_eq!(ranges, ShardPlanner::shard_ranges(units));
        assert!(ranges.len() <= ShardPlanner::TARGET_SHARDS);
        // contiguous exact cover of 0..units
        let mut cursor = 0;
        for &(u0, u1) in &ranges {
            assert_eq!(u0, cursor);
            assert!(u1 >= u0);
            cursor = u1;
        }
        assert_eq!(cursor, units);
    }
}

#[test]
fn sharded_forward_and_back_are_bit_identical_at_every_worker_count() {
    let plan = test_plan();
    let mut rng = Rng::new(901);
    let mut x = plan.new_vol();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    let mut y = plan.new_sino();
    rng.fill_uniform(&mut y.data, -1.0, 1.0);
    let fwd_ref = plan.forward(&x);
    let back_ref = plan.back(&y);
    for count in [0usize, 1, 2, 4] {
        let server = Arc::new(ShardServer::start_with("127.0.0.1:0", fast_opts()).unwrap());
        let handles = spawn_workers(&server.addr.to_string(), count);
        wait_for_workers(&server, count);
        let op = ShardedOp::new(plan.clone(), server.clone());
        let fwd = op.forward(&x);
        assert_eq!(
            fwd.data, fwd_ref.data,
            "{count} workers: sharded forward differs from in-process"
        );
        let back = op.back(&y);
        assert_eq!(
            back.data, back_ref.data,
            "{count} workers: sharded back differs from in-process"
        );
        drop(op);
        drop(server); // workers see EOF and exit
        for h in handles {
            h.join().unwrap();
        }
    }
}

#[test]
fn worker_death_mid_shard_re_scatters_to_a_survivor() {
    let plan = test_plan();
    let mut rng = Rng::new(902);
    let mut x = plan.new_vol();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    let reference = plan.forward(&x);

    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", fast_opts()).unwrap());
    let addr = server.addr.to_string();
    let survivor = spawn_workers(&addr, 1);
    wait_for_workers(&server, 1);

    // a saboteur that registers, accepts exactly one shard, and
    // vanishes with it in flight — the coordinator must notice the lost
    // connection and re-scatter that shard to the survivor
    let saboteur = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut sock = TcpStream::connect(&addr).unwrap();
            let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
            write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
            let reply = read_frame(&mut sock).unwrap().expect("hello reply");
            assert_eq!(reply.kind, FrameKind::Hello);
            let task = read_frame(&mut sock).unwrap().expect("a dispatched shard");
            assert_eq!(task.kind, FrameKind::Request);
            // drop the socket with the shard unanswered
        })
    };
    wait_for_workers(&server, 2);

    let op = ShardedOp::new(plan.clone(), server.clone());
    let fwd = op.forward(&x);
    assert_eq!(fwd.data, reference.data, "a mid-shard worker death must not change the bits");
    saboteur.join().unwrap();

    // the re-scatter is visible in the shard channel's telemetry
    let stats = server.telemetry().to_json();
    let retries = stats
        .get("shard_fp")
        .and_then(|row| row.get_f64("retries"))
        .expect("shard_fp telemetry row with a retries column");
    assert!(retries >= 1.0, "the lost shard must have been re-dispatched (got {retries})");

    drop(op);
    drop(server);
    for h in survivor {
        h.join().unwrap();
    }
}

#[test]
fn exhausted_retry_budget_surfaces_a_typed_remote_error() {
    // transport-level: a worker that answers every shard with an Error
    // frame, against a zero-retry budget — the submitter must get the
    // typed LeapError::Remote back, not a hang or a panic
    let opts = ShardServerOptions { max_retries: 0, ..fast_opts() };
    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", opts).unwrap());
    let addr = server.addr.to_string();
    let refuser = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
        write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
        let _ = read_frame(&mut sock).unwrap().expect("hello reply");
        // keep refusing until the server closes the channel
        while let Ok(Some(task)) = read_frame(&mut sock) {
            if task.kind != FrameKind::Request {
                continue;
            }
            let e = LeapError::Backend("saboteur declines".into());
            if write_frame(&mut sock, &Frame::error(task.id, &e)).is_err() {
                break;
            }
        }
    });
    wait_for_workers(&server, 1);

    let meta = Json::obj(vec![("shard", Json::Str("fp".into()))]);
    let pending = server.submit("shard_fp", meta, Arc::new(vec![0.0f32; 4]), 4);
    let err = pending.wait().expect_err("a refused shard with no retries must fail");
    match err {
        LeapError::Remote { code, ref message } => {
            assert_eq!(code, leap::api::codes::BACKEND, "the worker's error code must survive");
            assert!(message.contains("saboteur declines"), "unexpected message: {message}");
        }
        other => panic!("expected LeapError::Remote, got {other:?}"),
    }
    drop(server);
    refuser.join().unwrap();
}

#[test]
fn a_submit_with_no_registered_workers_fails_fast_instead_of_hanging() {
    // transport-level contract: a queued shard must never wait forever
    // for a worker that may never come — with nothing registered, the
    // event loop fails it with a typed Remote error so the operator
    // layer's in-process fallback runs
    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", fast_opts()).unwrap());
    let meta = Json::obj(vec![("shard", Json::Str("fp".into()))]);
    let pending = server.submit("shard_fp", meta, Arc::new(vec![0.0f32; 4]), 4);
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(pending.wait());
    });
    let res = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("a workerless submit must fail promptly, not hang");
    match res {
        Err(LeapError::Remote { code, ref message }) => {
            assert_eq!(code, leap::api::codes::IO);
            assert!(message.contains("no workers"), "unexpected message: {message}");
        }
        other => panic!("expected Err(LeapError::Remote), got {other:?}"),
    }
}

#[test]
fn every_worker_dying_mid_request_still_completes_via_in_process_fallback() {
    // the documented promise: "a request completes even if every worker
    // dies mid-solve". One saboteur registers, takes a shard, and
    // vanishes — its in-flight shard is requeued and then, with zero
    // workers left, the whole queue is failed over to the in-process
    // path, bit-identically
    let plan = test_plan();
    let mut rng = Rng::new(903);
    let mut x = plan.new_vol();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    let mut y = plan.new_sino();
    rng.fill_uniform(&mut y.data, -1.0, 1.0);
    let fwd_ref = plan.forward(&x);
    let back_ref = plan.back(&y);

    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", fast_opts()).unwrap());
    let addr = server.addr.to_string();
    let saboteur = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
        write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
        let _ = read_frame(&mut sock).unwrap().expect("hello reply");
        let task = read_frame(&mut sock).unwrap().expect("a dispatched shard");
        assert_eq!(task.kind, FrameKind::Request);
        // vanish with the shard in flight and others still queued
    });
    wait_for_workers(&server, 1);

    let op = ShardedOp::new(plan.clone(), server.clone());
    let fwd = op.forward(&x);
    assert_eq!(fwd.data, fwd_ref.data, "total worker loss must not change the bits");
    saboteur.join().unwrap();
    // by now the channel is workerless; back runs the pure fallback
    let back = op.back(&y);
    assert_eq!(back.data, back_ref.data, "workerless back must equal in-process");
}

#[test]
fn a_busy_worker_computing_past_the_heartbeat_timeout_is_not_dropped() {
    // a single-threaded worker sends nothing while computing a shard;
    // the coordinator must not mistake that silence for death while the
    // shard is in flight (the per-shard deadline bounds it instead).
    // max_retries=0 makes the failure mode sharp: a wrongly-dropped
    // worker means an immediate Err instead of the reply
    let opts = ShardServerOptions {
        heartbeat_timeout: Duration::from_millis(300),
        task_deadline: Duration::from_secs(10),
        max_retries: 0,
    };
    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", opts).unwrap());
    let addr = server.addr.to_string();
    let slow = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
        write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
        let _ = read_frame(&mut sock).unwrap().expect("hello reply");
        let task = read_frame(&mut sock).unwrap().expect("a dispatched shard");
        assert_eq!(task.kind, FrameKind::Request);
        // "compute" for 3x the heartbeat timeout: no frames, no
        // heartbeats — a worker deep in a long back projection
        std::thread::sleep(Duration::from_millis(900));
        write_frame_parts(
            &mut sock,
            FrameKind::Response,
            task.id,
            &Json::Null,
            &[5.0f32, 6.0, 7.0, 8.0],
        )
        .unwrap();
        // stay connected until the server closes the channel
        while let Ok(Some(_)) = read_frame(&mut sock) {}
    });
    wait_for_workers(&server, 1);

    let meta = Json::obj(vec![("shard", Json::Str("fp".into()))]);
    let pending = server.submit("shard_fp", meta, Arc::new(vec![0.0f32; 4]), 4);
    let out = pending.wait().expect("a slow-but-healthy worker's reply must be accepted");
    assert_eq!(out, vec![5.0, 6.0, 7.0, 8.0]);
    assert_eq!(server.workers(), 1, "the busy worker must not have been heartbeat-dropped");
    drop(server);
    slow.join().unwrap();
}

#[test]
fn a_retried_shard_prefers_a_different_idle_worker() {
    // worker A fails a shard; with B also idle, the retry must go to B
    // — A's slot looks free but a deadline-missing A would still be
    // serially computing the stale shard
    let opts = ShardServerOptions {
        heartbeat_timeout: Duration::from_secs(10),
        task_deadline: Duration::from_secs(10),
        max_retries: 2,
    };
    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", opts).unwrap());
    let addr = server.addr.to_string();

    // A registers first, so the first dispatch deterministically picks it
    let a = {
        let addr = addr.clone();
        std::thread::spawn(move || -> usize {
            let mut sock = TcpStream::connect(&addr).unwrap();
            let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
            write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
            let _ = read_frame(&mut sock).unwrap().expect("hello reply");
            let task = read_frame(&mut sock).unwrap().expect("the first dispatch");
            assert_eq!(task.kind, FrameKind::Request);
            let e = LeapError::Backend("worker A declines".into());
            write_frame(&mut sock, &Frame::error(task.id, &e)).unwrap();
            // count anything re-dispatched to us until the channel closes
            let mut extra = 0;
            while let Ok(Some(f)) = read_frame(&mut sock) {
                if f.kind == FrameKind::Request {
                    extra += 1;
                }
            }
            extra
        })
    };
    wait_for_workers(&server, 1);
    let b = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut sock = TcpStream::connect(&addr).unwrap();
            let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
            write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
            let _ = read_frame(&mut sock).unwrap().expect("hello reply");
            let task = read_frame(&mut sock).unwrap().expect("the retried dispatch");
            assert_eq!(task.kind, FrameKind::Request);
            write_frame_parts(
                &mut sock,
                FrameKind::Response,
                task.id,
                &Json::Null,
                &[1.0f32, 2.0, 3.0, 4.0],
            )
            .unwrap();
            while let Ok(Some(_)) = read_frame(&mut sock) {}
        })
    };
    wait_for_workers(&server, 2);

    let meta = Json::obj(vec![("shard", Json::Str("fp".into()))]);
    let pending = server.submit("shard_fp", meta, Arc::new(vec![0.0f32; 4]), 4);
    let out = pending.wait().expect("the retry via worker B must succeed");
    assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    drop(server);
    assert_eq!(a.join().unwrap(), 0, "the retry must not go back to the worker that failed it");
    b.join().unwrap();
}

#[test]
fn heartbeats_keep_idle_workers_alive_and_silence_drops_them() {
    let opts = ShardServerOptions {
        heartbeat_timeout: Duration::from_millis(600),
        ..fast_opts()
    };
    let server = Arc::new(ShardServer::start_with("127.0.0.1:0", opts).unwrap());
    let addr = server.addr.to_string();

    // a real worker heartbeating well under the timeout stays connected
    // across several timeout windows of pure idleness
    let live = spawn_workers(&addr, 1);
    wait_for_workers(&server, 1);

    // a mute that registers and then never sends another byte
    let mute = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut sock = TcpStream::connect(&addr).unwrap();
            let hello = Json::obj(vec![("role", Json::Str("worker".into()))]);
            write_frame_parts(&mut sock, FrameKind::Hello, 0, &hello, &[]).unwrap();
            let _ = read_frame(&mut sock).unwrap();
            // hold the socket open, silently, until the server drops us
            let mut buf = [0u8; 64];
            use std::io::Read as _;
            while let Ok(n) = sock.read(&mut buf) {
                if n == 0 {
                    break;
                }
            }
        })
    };
    wait_for_workers(&server, 2);

    // past the silence window: the mute is gone, the heartbeater is not
    let t0 = Instant::now();
    while server.workers() != 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "a silent worker must be dropped after the heartbeat timeout"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    std::thread::sleep(Duration::from_millis(900));
    assert_eq!(server.workers(), 1, "a heartbeating idle worker must never be dropped");

    drop(server);
    mute.join().unwrap();
    for h in live {
        h.join().unwrap();
    }
}
