//! Protocol-level integration tests: v2 frame round-trips for every op
//! variant and odd tensor sizes, malformed/truncated-frame and
//! version-mismatch rejection, typed error codes end to end, and
//! v1-JSON-client-against-v2-server compatibility — all against the real
//! TCP stack.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;

use leap::api::{codes, LeapError, ScanBuilder};
use leap::coordinator::request::{request_from_frame, request_to_frame};
use leap::coordinator::server::{BinaryClient, Client, Server};
use leap::coordinator::wire::{self, Frame, FrameKind};
use leap::coordinator::{
    BatchPolicy, Coordinator, Executor, NativeExecutor, Op, Router, SessionExecutor,
};
use leap::geometry::config::ScanConfig;
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::projector::{Model, Projector};
use leap::util::json::Json;
use leap::util::rng::Rng;

fn scan_config() -> ScanConfig {
    ScanConfig {
        geometry: Geometry::Parallel(ParallelBeam::standard_2d(12, 30, 1.0)),
        volume: VolumeGeometry::slice2d(20, 20, 1.0),
    }
}

fn start_server() -> (Server, Arc<Coordinator>) {
    let cfg = scan_config();
    let native = NativeExecutor::new(
        Projector::new(cfg.geometry.clone(), cfg.volume.clone(), Model::SF).with_threads(2),
    );
    let router: Arc<dyn Executor> = Arc::new(Router::new(vec![
        Arc::new(native),
        Arc::new(SessionExecutor::new()),
    ]));
    let coord = Arc::new(Coordinator::new(router, BatchPolicy::default(), 1 << 28, 2));
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    (server, coord)
}

#[test]
fn frame_roundtrip_every_op_variant_and_odd_sizes() {
    // encode→decode bit-identity for every Op variant × odd tensor sizes
    let variants = vec![
        Op::NativeFp,
        Op::NativeBp,
        Op::NativeFbp,
        Op::SessionFp(1),
        Op::SessionBp(u64::MAX),
        Op::SessionFbp(7),
        Op::SessionPipelineGrad { session: (1u64 << 53) + 1, pipeline: u64::MAX },
        Op::Artifact("fp_sf".into()),
    ];
    let mut rng = Rng::new(42);
    for (vi, op) in variants.iter().enumerate() {
        for n in [0usize, 1, 3, 17, 255, 1001] {
            let mut payload = vec![0.0f32; n];
            rng.fill_uniform(&mut payload, -1e6, 1e6);
            let id = (vi * 10_000 + n) as u64;
            let frame = request_to_frame(id, op, payload.clone());
            let decoded = wire::decode_frame(&wire::encode_frame(&frame).unwrap()).unwrap();
            let req = request_from_frame(decoded).unwrap();
            assert_eq!(&req.op, op, "op variant {vi} must survive the wire");
            assert_eq!(req.id, id);
            let sent: Vec<u32> = payload.iter().map(|v| v.to_bits()).collect();
            let got: Vec<u32> = req.inputs[0].iter().map(|v| v.to_bits()).collect();
            assert_eq!(sent, got, "payload bits, variant {vi}, n={n}");
        }
    }
}

#[test]
fn truncated_frames_are_typed_protocol_errors() {
    let frame = request_to_frame(5, &Op::SessionFp(3), vec![1.0, 2.0, 3.0]);
    let bytes = wire::encode_frame(&frame).unwrap();
    for cut in 0..bytes.len() {
        match wire::decode_frame(&bytes[..cut]) {
            Err(LeapError::Protocol(_)) => {}
            other => panic!("cut at {cut}: expected Protocol error, got {other:?}"),
        }
    }
    // and the full frame still decodes
    assert!(wire::decode_frame(&bytes).is_ok());
}

#[test]
fn version_mismatch_rejected_locally_and_over_tcp() {
    let mut bytes =
        wire::encode_frame(&Frame::new(FrameKind::Hello, 0, Json::Null, vec![])).unwrap();
    bytes[4] = 7;
    assert_eq!(
        wire::decode_frame(&bytes).unwrap_err(),
        LeapError::VersionMismatch { got: 7, want: wire::VERSION }
    );

    let (server, _coord) = start_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(stream);
    writer.write_all(&bytes).unwrap();
    writer.flush().unwrap();
    let reply = wire::read_frame(&mut reader).unwrap().expect("typed error frame");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.to_error().code(), codes::VERSION_MISMATCH);
}

#[test]
fn malformed_frame_rejected_over_tcp() {
    let (server, _coord) = start_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(stream);
    // valid magic/version, payload length not a multiple of 4
    let mut bytes =
        wire::encode_frame(&Frame::new(FrameKind::Request, 1, Json::Null, vec![])).unwrap();
    bytes[20..24].copy_from_slice(&5u32.to_le_bytes());
    writer.write_all(&bytes).unwrap();
    writer.flush().unwrap();
    let reply = wire::read_frame(&mut reader).unwrap().expect("typed error frame");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.to_error().code(), codes::PROTOCOL);
}

#[test]
fn v1_json_client_against_v2_server_stays_compatible() {
    // one server; a legacy v1 JSON client and a v2 binary session client
    // drive the same projection and must agree bit for bit with the
    // in-process api path
    let (server, _coord) = start_server();
    let cfg = scan_config();
    let scan = ScanBuilder::from_config(&cfg).model(Model::SF).threads(2).build().unwrap();
    let mut vol = vec![0.0f32; scan.volume_len()];
    Rng::new(11).fill_uniform(&mut vol, 0.0, 1.0);
    let reference = scan.forward(&vol).unwrap();

    let mut v2 = BinaryClient::connect(&server.addr).unwrap();
    let session = v2.open_session(&cfg, Model::SF, Some(2)).unwrap();
    let from_v2 = v2.forward(session, &vol).unwrap();
    assert_eq!(from_v2, reference, "v2 must be bit-identical to in-process");

    let mut v1 = Client::connect(&server.addr).unwrap();
    let from_v1 = v1.call_tensor("native_fp", &vol).unwrap();
    assert_eq!(from_v1, reference, "v1 JSON must be bit-identical to in-process");

    // v1 error replies now carry the typed code alongside the message,
    // and call_tensor reconstructs the typed error from it
    let bad = v1.call("native_fp", &[&[1.0, 2.0]]).unwrap();
    assert_eq!(bad.get_f64("code"), Some(codes::SHAPE_MISMATCH as f64));
    assert!(bad.get_str("error").unwrap().contains("shape mismatch"));
    let typed = v1.call_tensor("native_fp", &[1.0, 2.0]).unwrap_err();
    assert_eq!(typed.code(), codes::SHAPE_MISMATCH, "{typed:?}");
}

#[test]
fn session_fbp_and_batched_sessions_agree_with_local() {
    let (server, _coord) = start_server();
    let cfg = scan_config();
    let scan = ScanBuilder::from_config(&cfg).model(Model::SF).threads(2).build().unwrap();
    let truth = leap::phantom::shepp::shepp_logan_2d(8.0, 0.02).rasterize(scan.volume(), 2);
    let sino = scan.forward(&truth.data).unwrap();

    let mut client = BinaryClient::connect(&server.addr).unwrap();
    let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
    let served_fbp = client.fbp(session, &sino).unwrap();
    let local_fbp = scan
        .solve(leap::api::Solver::Fbp { window: leap::recon::Window::Hann }, &sino)
        .unwrap();
    assert_eq!(served_fbp, local_fbp, "session fbp must match the api path");

    // several in-flight session requests (dynamic batching may group
    // them) all return the same bits
    let reference = scan.forward(&truth.data).unwrap();
    let mut handles = Vec::new();
    let addr = server.addr;
    for c in 0..3 {
        let cfg = cfg.clone();
        let vol = truth.data.clone();
        let reference = reference.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = BinaryClient::connect(&addr).unwrap();
            let s = cl.open_session(&cfg, Model::SF, Some(2)).unwrap();
            for _ in 0..4 {
                let out = cl.forward(s, &vol).unwrap();
                assert_eq!(out, reference, "client {c}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn registered_pipeline_grads_are_bit_identical_over_the_wire() {
    // the acceptance path: register an unrolled pipeline on a session,
    // request loss+gradients over protocol v2, and compare every bit
    // against the in-process tape on the same (cached) plan
    let (server, _coord) = start_server();
    let cfg = scan_config();
    let scan = ScanBuilder::from_config(&cfg).model(Model::SF).threads(2).build().unwrap();
    let local: Arc<dyn leap::ops::LinearOp> =
        Arc::new(leap::ops::PlanOp::from_plan(scan.plan().clone()));
    let pipe = leap::tape::unrolled_gd(
        local,
        &leap::tape::UnrollCfg { iterations: 3, step_init: 0.005, nonneg: true },
    )
    .unwrap();

    let mut client = BinaryClient::connect(&server.addr).unwrap();
    let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
    let pid = client.register_pipeline(session, &pipe).unwrap();

    let mut rng = Rng::new(77);
    let mut truth = vec![0.0f32; scan.volume_len()];
    rng.fill_uniform(&mut truth, 0.1, 1.0);
    let sino = scan.forward(&truth).unwrap();
    let params: Vec<Vec<f32>> = pipe
        .params()
        .iter()
        .map(|p| {
            let mut v = vec![0.0f32; p.shape.numel()];
            rng.fill_uniform(&mut v, 0.002, 0.01);
            v
        })
        .collect();
    let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let inputs: Vec<&[f32]> = vec![&sino, &truth];
    let (served_loss, served_grads) =
        client.pipeline_grad(session, pid, &pipe, &pr, &inputs).unwrap();
    let (local_loss, local_grads) = pipe.loss_and_grads_with(&pr, &inputs).unwrap();
    assert_eq!(served_loss.to_bits(), local_loss.to_bits(), "loss bits over the wire");
    assert_eq!(served_grads, local_grads, "gradient bits over the wire");

    // Malformed registrations are typed and the OWNING connection
    // survives. BinaryClient does not expose raw frames, so hand-roll a
    // connection that opens its own session first (connection scoping
    // would otherwise reject the bad spec as UnknownSession before spec
    // validation ever runs).
    {
        use leap::geometry::config::{geometry_to_json, volume_to_json};
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let open = Frame::new(
            FrameKind::OpenSession,
            0,
            Json::obj(vec![(
                "config",
                Json::obj(vec![
                    ("geometry", geometry_to_json(&cfg.geometry)),
                    ("volume", volume_to_json(&cfg.volume)),
                ]),
            )]),
            Vec::new(),
        );
        writer.write_all(&wire::encode_frame(&open).unwrap()).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("open reply");
        assert_eq!(reply.kind, FrameKind::OpenSession);
        let own_session = reply.id;
        // a bad spec on the session's own connection: spec validation
        // must answer (Protocol), NOT the not-yours path
        let bad_meta = Json::obj(vec![("pipeline", Json::Str("nonsense".into()))]);
        let bad = Frame::new(FrameKind::RegisterPipeline, own_session, bad_meta, Vec::new());
        writer.write_all(&wire::encode_frame(&bad).unwrap()).unwrap();
        writer.flush().unwrap();
        let e = wire::read_frame(&mut reader).unwrap().expect("error reply").to_error();
        assert_eq!(e.code(), codes::PROTOCOL, "spec validation must run: {e:?}");
        // and the connection is still usable afterwards
        let close = Frame::new(FrameKind::CloseSession, own_session, Json::Null, Vec::new());
        writer.write_all(&wire::encode_frame(&close).unwrap()).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("close reply");
        assert_eq!(reply.kind, FrameKind::CloseSession, "connection must survive the bad spec");
    }

    client.close_session(session).unwrap();
}

#[test]
fn open_session_validates_geometry_with_typed_codes() {
    let (server, _coord) = start_server();
    let mut client = BinaryClient::connect(&server.addr).unwrap();
    let mut bad = scan_config();
    bad.volume.vx = -1.0; // finite (survives JSON) but degenerate
    let e = client.open_session(&bad, Model::SF, None).unwrap_err();
    assert_eq!(e.code(), codes::INVALID_GEOMETRY, "{e:?}");
    // the same connection still opens a valid session afterwards
    let id = client.open_session(&scan_config(), Model::SF, None).unwrap();
    assert!(client.close_session(id).is_ok());
    let e = client.close_session(id).unwrap_err();
    assert_eq!(e.code(), codes::UNKNOWN_SESSION, "{e:?}");
}
