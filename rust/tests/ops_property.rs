//! Operator-layer properties, swept generically over every
//! [`leap::ops::LinearOp`] implementation in the crate:
//!
//! * **Adjoint identity** `⟨Ax, y⟩ = ⟨x, Aᵀy⟩` — the matched-pair
//!   property the paper's differentiability claim rests on — for the
//!   planned projector across all 3 models × 5 geometries, the stored
//!   system matrix, the ramp filter, and every combinator
//!   (Scaled/Composed/RowMasked/Normal) wrapping them.
//! * **Batched ≡ sequential** — a stacked `apply_batch_into` must be
//!   bit-identical to per-item applies for every model × geometry.
//! * **Finite-difference gradients** — `ProjectionLoss` (½‖Ax−b‖² and
//!   Poisson NLL) against central differences for plain, masked and
//!   matrix-backed operators.

use leap::geometry::{ConeBeam, FanBeam, Geometry, ModularBeam, ParallelBeam, VolumeGeometry};
use leap::ops::{
    Composed, LinearOp, Normal, Objective, PlanOp, ProjectionLoss, RampFilterOp, RowMasked,
    Scaled, Shape,
};
use leap::projector::{Model, Projector};
use leap::recon::Window;
use leap::sysmatrix::SystemMatrix;
use leap::util::{dot_f64, rng::Rng};

fn all_geometries() -> Vec<Geometry> {
    let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
    let mut curved = cone.clone();
    curved.shape = leap::geometry::DetectorShape::Curved;
    vec![
        Geometry::Parallel(ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2)),
        Geometry::Fan(FanBeam::standard(5, 14, 1.3, 50.0, 100.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
    ]
}

fn vg_for(geom: &Geometry) -> VolumeGeometry {
    if matches!(geom, Geometry::Fan(_)) {
        VolumeGeometry::slice2d(9, 9, 1.0)
    } else {
        VolumeGeometry::cube(8, 1.0)
    }
}

fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

/// Relative adjoint gap of any operator, generic over `&dyn LinearOp`.
fn adjoint_gap(op: &dyn LinearOp, rng: &mut Rng) -> f64 {
    let x = rand_vec(op.domain_shape().numel(), rng);
    let y = rand_vec(op.range_shape().numel(), rng);
    let ax = op.apply(&x);
    let aty = op.adjoint(&y);
    let lhs = dot_f64(&ax, &y);
    let rhs = dot_f64(&x, &aty);
    (lhs - rhs).abs() / lhs.abs().max(rhs.abs()).max(1e-12)
}

fn assert_adjoint(op: &dyn LinearOp, tol: f64, what: &str, rng: &mut Rng) {
    let gap = adjoint_gap(op, rng);
    assert!(gap < tol, "{what}: adjoint gap {gap}");
}

/// Adjoint tolerance for projector-backed operators: exact only on the
/// f32 storage tier — a reduced tier's Aᵀ reads a quantized sinogram, so
/// under a 16-bit LEAP_STORAGE default (the CI matrix axis) the identity
/// holds to the tier's accuracy class instead (docs/MEMORY.md).
fn projector_adjoint_tol() -> f64 {
    if leap::precision::default_tier() == leap::StorageTier::F32 { 5e-5 } else { 5e-3 }
}

#[test]
fn adjoint_identity_sweeps_every_operator() {
    let mut rng = Rng::new(1234);
    let tol = projector_adjoint_tol();
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let name = format!("{}/{}", model.name(), geom.kind());
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(2);
            let a = PlanOp::new(&p);
            assert_adjoint(&a, tol, &format!("{name} PlanOp"), &mut rng);
            assert_adjoint(&Scaled::new(&a, -1.75), tol, &format!("{name} Scaled"), &mut rng);
            let nviews = a.range_shape().0[0];
            let mask: Vec<f32> = (0..nviews)
                .map(|v| match v % 3 {
                    0 => 1.0,
                    1 => 0.0,
                    _ => 0.5,
                })
                .collect();
            assert_adjoint(
                &RowMasked::new(&a, mask),
                tol,
                &format!("{name} RowMasked"),
                &mut rng,
            );
            assert_adjoint(&Normal::new(&a), tol, &format!("{name} Normal"), &mut rng);
            let filt = RampFilterOp::for_scan(&geom, Window::Hann);
            assert_adjoint(
                &Composed::new(&filt, &a),
                tol.max(5e-4),
                &format!("{name} ramp∘A"),
                &mut rng,
            );
        }
    }
}

#[test]
fn adjoint_identity_system_matrix_and_combinators() {
    let mut rng = Rng::new(77);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            if model == Model::SF && matches!(geom, Geometry::Modular(_)) {
                continue; // SF system matrix undefined for modular beams
            }
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(1);
            let mat = SystemMatrix::build(&p);
            let name = format!("matrix {}/{}", model.name(), geom.kind());
            assert_adjoint(&mat, 5e-5, &name, &mut rng);
            assert_adjoint(&Normal::new(&mat), 5e-5, &format!("{name} Normal"), &mut rng);
        }
    }
}

#[test]
fn ramp_filter_is_self_adjoint_across_windows() {
    let mut rng = Rng::new(9);
    let geom = Geometry::Parallel(ParallelBeam::standard_3d(5, 4, 24, 1.0, 1.0));
    for window in [Window::RamLak, Window::SheppLogan, Window::Cosine, Window::Hann] {
        let f = RampFilterOp::for_scan(&geom, window);
        assert_adjoint(&f, 1e-5, &format!("ramp {}", window.name()), &mut rng);
    }
}

#[test]
fn batched_apply_bit_identical_for_every_model_and_geometry() {
    let mut rng = Rng::new(4242);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(3);
            let op = PlanOp::new(&p);
            let dn = op.domain_shape().numel();
            let rn = op.range_shape().numel();
            let batch = 3;
            let xs = rand_vec(batch * dn, &mut rng);
            let mut ys = vec![0.0f32; batch * rn];
            op.apply_batch_into(batch, &xs, &mut ys);
            for b in 0..batch {
                let single = op.apply(&xs[b * dn..(b + 1) * dn]);
                assert_eq!(
                    ys[b * rn..(b + 1) * rn],
                    single[..],
                    "{}/{} forward item {b}",
                    model.name(),
                    geom.kind()
                );
            }
            let ss = rand_vec(batch * rn, &mut rng);
            let mut vs = vec![0.0f32; batch * dn];
            op.adjoint_batch_into(batch, &ss, &mut vs);
            for b in 0..batch {
                let single = op.adjoint(&ss[b * rn..(b + 1) * rn]);
                assert_eq!(
                    vs[b * dn..(b + 1) * dn],
                    single[..],
                    "{}/{} back item {b}",
                    model.name(),
                    geom.kind()
                );
            }
        }
    }
}

/// Directional finite-difference check of `∇L` along a random direction.
fn fd_gap(loss: &ProjectionLoss, x: &[f32], n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut d = vec![0.0f32; n];
    rng.fill_uniform(&mut d, -1.0, 1.0);
    let mut grad = vec![0.0f32; n];
    loss.value_and_grad(x, &mut grad);
    let analytic: f64 = grad.iter().zip(d.iter()).map(|(&g, &v)| g as f64 * v as f64).sum();
    let h = 1e-3f32;
    let xp: Vec<f32> = x.iter().zip(d.iter()).map(|(&a, &v)| a + h * v).collect();
    let xm: Vec<f32> = x.iter().zip(d.iter()).map(|(&a, &v)| a - h * v).collect();
    let fd = (loss.value(&xp) - loss.value(&xm)) / (2.0 * h as f64);
    (analytic - fd).abs() / analytic.abs().max(fd.abs()).max(1e-9)
}

#[test]
fn projection_loss_gradients_pass_fd_for_plain_masked_and_matrix_ops() {
    let vg = VolumeGeometry::slice2d(10, 10, 1.0);
    let geom = Geometry::Parallel(ParallelBeam::standard_2d(8, 14, 1.0));
    let p = Projector::new(geom.clone(), vg.clone(), Model::SF).with_threads(2);
    let plan_op = PlanOp::new(&p);
    let mat = SystemMatrix::build(&p.clone().with_threads(1));
    let n = vg.num_voxels();
    let mut rng = Rng::new(88);
    let mut x = vec![0.0f32; n];
    rng.fill_uniform(&mut x, 0.2, 1.0);
    let mut truth = vec![0.0f32; n];
    rng.fill_uniform(&mut truth, 0.2, 1.0);

    let mask: Vec<f32> = (0..8).map(|v| if v < 5 { 1.0 } else { 0.0 }).collect();
    let masked = RowMasked::new(&plan_op, mask);

    let ops: Vec<(&str, &dyn LinearOp)> =
        vec![("plan", &plan_op), ("masked", &masked), ("matrix", &mat)];
    for (name, op) in ops {
        let b = op.apply(&truth);
        for objective in [Objective::LeastSquares, Objective::PoissonNll] {
            let loss = ProjectionLoss::new(op, &b, objective);
            let gap = fd_gap(&loss, &x, n, 7);
            assert!(gap < 1e-2, "{name} {objective:?}: fd gap {gap}");
        }
    }
}

#[test]
fn solver_cores_accept_masked_operators() {
    // the DC-refinement shape, but driven purely through the operator
    // layer: a RowMasked operator + sirt_op reproduces the view_mask
    // option of the concrete solver
    let vg = VolumeGeometry::slice2d(16, 16, 1.0);
    let geom = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
    let p = Projector::new(geom, vg.clone(), Model::SF).with_threads(2);
    let truth = leap::phantom::shepp::shepp_logan_2d(7.0, 0.02).rasterize(&vg, 2);
    let y = p.forward(&truth);
    let mask: Vec<f32> = (0..12).map(|v| if v < 8 { 1.0 } else { 0.0 }).collect();

    let op = PlanOp::new(&p);
    let x0 = vec![0.0f32; vg.num_voxels()];
    let opts = leap::recon::SirtOpts {
        iterations: 8,
        view_mask: Some(mask.clone()),
        ..Default::default()
    };
    let (via_option, _) = leap::recon::sirt_op(&op, &y.data, &x0, &opts);

    // the same solve via RowMasked: mask the data once, drop the option
    let masked_op = RowMasked::new(&op, mask.clone());
    let mut y_masked = y.data.clone();
    leap::recon::sirt::apply_view_mask_flat(&mut y_masked, &mask, y.nrows * y.ncols);
    let opts_plain = leap::recon::SirtOpts { iterations: 8, ..Default::default() };
    let (via_masked_op, _) = leap::recon::sirt_op(&masked_op, &y_masked, &x0, &opts_plain);

    // both paths mask the residual identically (M is 0/1 diagonal and
    // M·y is premasked), so the iterates agree to float accuracy
    for i in 0..via_option.len() {
        assert!(
            (via_option[i] - via_masked_op[i]).abs() < 1e-5,
            "idx {i}: {} vs {}",
            via_option[i],
            via_masked_op[i]
        );
    }
}

#[test]
fn shape_reports_match_containers() {
    let vg = VolumeGeometry::cube(6, 1.0);
    let geom = Geometry::Cone(ConeBeam::standard(4, 5, 7, 1.5, 1.5, 40.0, 80.0));
    let p = Projector::new(geom.clone(), vg.clone(), Model::SF).with_threads(1);
    let op = PlanOp::new(&p);
    assert_eq!(op.domain_shape(), Shape([6, 6, 6]));
    assert_eq!(op.range_shape(), Shape([4, 5, 7]));
    assert_eq!(op.domain_shape().numel(), p.new_vol().len());
    assert_eq!(op.range_shape().numel(), p.new_sino().len());
}
