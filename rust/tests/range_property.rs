//! Property tests for the range-restricted executors on
//! [`leap::projector::plan::ProjectionPlan`] — the per-tile kernels the
//! out-of-core scheduler (`leap::vol`) is built on.
//!
//! The stitching contract (PR 7, re-stated in docs/MEMORY.md): a range
//! executor zeroes and writes only the output its range owns — sinogram
//! view slabs for `forward_range_into_with_threads`, backprojection
//! shard units for `back_range_into_with_threads` — and runs the *same*
//! kernel the full-range path runs. So executing **any** partition of
//! the full range into one buffer reproduces the unsharded executor bit
//! for bit, for every model × geometry × executable backend (the 12
//! range executors: {forward, back} × {parallel, fan, cone} × {scalar,
//! simd}, plus the ray fallbacks the model sweep reaches).
//!
//! The sweep deliberately includes the degenerate shapes a tile
//! scheduler produces at the edges: empty ranges (`lo == hi`, at both
//! ends and mid-partition), single-element ranges, and uneven splits.

use leap::backend::BackendKind;
use leap::geometry::{
    ConeBeam, DetectorShape, FanBeam, Geometry, ModularBeam, ParallelBeam, VolumeGeometry,
};
use leap::projector::{Model, Projector};
use leap::util::rng::Rng;

/// One geometry per family (flat and curved cone detectors both count:
/// they take different footprint/ray code paths).
fn all_geometries() -> Vec<Geometry> {
    let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
    let mut curved = cone.clone();
    curved.shape = DetectorShape::Curved;
    vec![
        Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
        Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
    ]
}

fn vg_for(geom: &Geometry) -> VolumeGeometry {
    if matches!(geom, Geometry::Fan(_)) {
        VolumeGeometry::slice2d(12, 12, 1.0)
    } else {
        VolumeGeometry::cube(10, 1.0)
    }
}

const EXECUTABLE: [BackendKind; 2] = [BackendKind::Scalar, BackendKind::Simd];

/// Partitions of `0..n` a tile scheduler could plausibly emit: the full
/// range, a split with empty and single-element ranges at both ends and
/// in the middle, and uneven interior cuts.
fn partitions(n: usize) -> Vec<Vec<(usize, usize)>> {
    let mut out = vec![vec![(0, n)]];
    if n >= 2 {
        // empty head, single element, empty middle, bulk, empty tail
        out.push(vec![(0, 0), (0, 1), (1, 1), (1, n), (n, n)]);
        // uneven thirds (first cut deliberately small)
        let a = n / 3;
        let b = (a + (n - a) / 4 + 1).min(n);
        out.push(vec![(0, a), (a, b), (b, n)]);
        // all single-element ranges
        out.push((0..n).map(|i| (i, i + 1)).collect());
    }
    out
}

#[test]
fn stitched_forward_ranges_reproduce_the_full_executor_bit_for_bit() {
    let mut rng = Rng::new(811);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for kind in EXECUTABLE {
                let p = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(3)
                    .with_backend(kind);
                let plan = p.plan();
                let mut x = p.new_vol();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                let reference = plan.forward(&x);
                let nviews = plan.forward_shard_units();
                for parts in partitions(nviews) {
                    // NaN sentinel: any view slab a range fails to
                    // write stays NaN and can never equal the reference
                    let mut stitched = plan.new_sino();
                    stitched.data.fill(f32::NAN);
                    for &(v0, v1) in &parts {
                        plan.forward_range_into_with_threads(&x, &mut stitched, 2, v0, v1);
                    }
                    assert_eq!(
                        stitched.data,
                        reference.data,
                        "{}/{}/{}: forward partition {parts:?} does not stitch",
                        kind.name(),
                        model.name(),
                        p.geom.kind()
                    );
                }
            }
        }
    }
}

#[test]
fn stitched_back_ranges_reproduce_the_full_executor_bit_for_bit() {
    let mut rng = Rng::new(812);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for kind in EXECUTABLE {
                let p = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(3)
                    .with_backend(kind);
                let plan = p.plan();
                let mut y = p.new_sino();
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                let reference = plan.back(&y);
                let units = plan.back_shard_units();
                for parts in partitions(units) {
                    let mut stitched = plan.new_vol();
                    stitched.data.fill(f32::NAN);
                    for &(u0, u1) in &parts {
                        plan.back_range_into_with_threads(&y, &mut stitched, 2, u0, u1);
                    }
                    assert_eq!(
                        stitched.data,
                        reference.data,
                        "{}/{}/{}: back partition {parts:?} does not stitch",
                        kind.name(),
                        model.name(),
                        p.geom.kind()
                    );
                }
            }
        }
    }
}

#[test]
fn range_order_does_not_matter() {
    // ranges own disjoint output, so a scheduler may execute tiles in
    // any order (the LRU-driven order of `vol::TiledVol3` is not
    // ascending) — reversed stitching must still be bit-exact
    let mut rng = Rng::new(813);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        let p = Projector::new(geom.clone(), vg.clone(), Model::SF).with_threads(2);
        let plan = p.plan();
        let mut x = p.new_vol();
        rng.fill_uniform(&mut x.data, 0.0, 1.0);
        let reference = plan.forward(&x);
        let n = plan.forward_shard_units();
        let mut stitched = plan.new_sino();
        stitched.data.fill(f32::NAN);
        for v in (0..n).rev() {
            plan.forward_range_into_with_threads(&x, &mut stitched, 2, v, v + 1);
        }
        assert_eq!(stitched.data, reference.data, "{}: reversed forward order", p.geom.kind());
        let mut y = p.new_sino();
        rng.fill_uniform(&mut y.data, 0.0, 1.0);
        let back_ref = plan.back(&y);
        let units = plan.back_shard_units();
        let mut vol = plan.new_vol();
        vol.data.fill(f32::NAN);
        let mid = units / 2;
        for &(u0, u1) in &[(mid, units), (0, mid)] {
            plan.back_range_into_with_threads(&y, &mut vol, 2, u0, u1);
        }
        assert_eq!(vol.data, back_ref.data, "{}: reordered back halves", p.geom.kind());
    }
}

#[test]
fn empty_ranges_write_nothing() {
    // an empty range is a no-op, not "zero everything": the tile
    // scheduler calls executors for whatever slices the budget produces
    // and must be able to skip without disturbing neighbours
    let mut rng = Rng::new(814);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for kind in EXECUTABLE {
            let p = Projector::new(geom.clone(), vg.clone(), Model::SF)
                .with_threads(2)
                .with_backend(kind);
            let plan = p.plan();
            let mut x = p.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            const SENTINEL: f32 = 7.25;
            let mut sino = plan.new_sino();
            sino.data.fill(SENTINEL);
            let n = plan.forward_shard_units();
            for v in [0, n / 2, n] {
                plan.forward_range_into_with_threads(&x, &mut sino, 2, v, v);
            }
            assert!(
                sino.data.iter().all(|&s| s == SENTINEL),
                "{}/{}: empty forward range wrote output",
                kind.name(),
                p.geom.kind()
            );
            let mut y = p.new_sino();
            rng.fill_uniform(&mut y.data, 0.0, 1.0);
            let mut vol = plan.new_vol();
            vol.data.fill(SENTINEL);
            let units = plan.back_shard_units();
            for u in [0, units / 2, units] {
                plan.back_range_into_with_threads(&y, &mut vol, 2, u, u);
            }
            assert!(
                vol.data.iter().all(|&v| v == SENTINEL),
                "{}/{}: empty back range wrote output",
                kind.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn range_executors_are_thread_count_invariant() {
    // the per-range kernels inherit the slab/unit-ownership invariant:
    // the same range with 1 worker and with many workers produces the
    // same bits (the out-of-core scheduler leans on this to pick tile
    // parallelism by residency, not by semantics)
    let mut rng = Rng::new(815);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        let p = Projector::new(geom.clone(), vg.clone(), Model::SF).with_threads(1);
        let plan = p.plan();
        let mut x = p.new_vol();
        rng.fill_uniform(&mut x.data, 0.0, 1.0);
        let n = plan.forward_shard_units();
        let (v0, v1) = (n / 3, n);
        let mut a = plan.new_sino();
        let mut b = plan.new_sino();
        plan.forward_range_into_with_threads(&x, &mut a, 1, v0, v1);
        plan.forward_range_into_with_threads(&x, &mut b, 4, v0, v1);
        assert_eq!(a.data, b.data, "{}: forward range thread variance", p.geom.kind());
        let mut y = plan.new_sino();
        rng.fill_uniform(&mut y.data, 0.0, 1.0);
        let units = plan.back_shard_units();
        let (u0, u1) = (units / 4, units.div_ceil(2));
        let mut va = plan.new_vol();
        let mut vb = plan.new_vol();
        plan.back_range_into_with_threads(&y, &mut va, 1, u0, u1);
        plan.back_range_into_with_threads(&y, &mut vb, 4, u0, u1);
        assert_eq!(va.data, vb.data, "{}: back range thread variance", p.geom.kind());
    }
}

#[test]
fn tree_reduced_partial_volumes_reproduce_the_full_back_projection() {
    // the contract the cluster reducer (`leap::cluster::reduce`) relies
    // on: each shard backprojects its owned unit range into a fresh
    // zeroed volume (the shape workers return over the shard channel),
    // and combining those full-size partials with the fixed-order tree
    // reduction reproduces the unsharded executor bit for bit — for
    // arbitrary uneven partitions, including empty and single-unit
    // ranges. Ownership is disjoint, so every voxel sums one owned
    // value with exact zeros: no rounding at any tree shape.
    let mut rng = Rng::new(816);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for kind in EXECUTABLE {
            let p = Projector::new(geom.clone(), vg.clone(), Model::SF)
                .with_threads(2)
                .with_backend(kind);
            let plan = p.plan();
            let mut y = p.new_sino();
            rng.fill_uniform(&mut y.data, 0.0, 1.0);
            let reference = plan.back(&y);
            let units = plan.back_shard_units();
            for parts in partitions(units) {
                let partials: Vec<Vec<f32>> = parts
                    .iter()
                    .map(|&(u0, u1)| {
                        let mut partial = plan.new_vol();
                        plan.back_range_into_with_threads(&y, &mut partial, 2, u0, u1);
                        partial.data
                    })
                    .collect();
                let reduced = leap::cluster::reduce::tree_reduce(partials)
                    .expect("non-empty partition");
                assert_eq!(
                    reduced,
                    reference.data,
                    "{}/{}: tree-reduced partition {parts:?} differs from full back",
                    kind.name(),
                    p.geom.kind()
                );
            }
        }
    }
}
