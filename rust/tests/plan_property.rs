//! Property tests for the plan/execute split: for every projector model ×
//! every geometry family, the planned path (`forward_with_plan` /
//! `back_with_plan`) must be **bit-identical** to the direct
//! `forward_into`/`back_into` path, the adjoint identity must hold
//! through the plan, and plan reuse across many applications must be
//! deterministic.

use leap::geometry::{
    ConeBeam, DetectorShape, FanBeam, Geometry, HelicalCone, ModularBeam, ParallelBeam,
    VolumeGeometry,
};
use leap::projector::{Model, Projector};
use leap::util::{dot_f64, rng::Rng};

/// One geometry per family (flat and curved cone detectors both count:
/// they take different footprint/ray code paths), plus a helical
/// trajectory served through its modular-beam export — helical is a
/// first-class planned geometry and sweeps every property below.
fn all_geometries() -> Vec<Geometry> {
    let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
    let mut curved = cone.clone();
    curved.shape = DetectorShape::Curved;
    let helix = HelicalCone::standard(1.5, 8, 6, 10, 1.5, 1.5, 50.0, 100.0, 8.0);
    vec![
        Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
        Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
        Geometry::Modular(helix.to_modular()),
    ]
}

fn vg_for(geom: &Geometry) -> VolumeGeometry {
    if matches!(geom, Geometry::Fan(_)) {
        VolumeGeometry::slice2d(12, 12, 1.0)
    } else {
        VolumeGeometry::cube(10, 1.0)
    }
}

#[test]
fn plan_forward_bit_identical_all_models_all_geometries() {
    let mut rng = Rng::new(101);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(3);
            let plan = p.plan();
            let mut x = p.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            let direct = p.forward(&x);
            let mut planned = p.new_sino();
            p.forward_with_plan(&plan, &x, &mut planned);
            assert_eq!(
                direct.data,
                planned.data,
                "{}/{}: planned forward differs from direct",
                model.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn plan_back_bit_identical_all_models_all_geometries() {
    let mut rng = Rng::new(202);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(3);
            let plan = p.plan();
            let mut y = p.new_sino();
            rng.fill_uniform(&mut y.data, -1.0, 1.0);
            let direct = p.back(&y);
            let mut planned = p.new_vol();
            p.back_with_plan(&plan, &y, &mut planned);
            assert_eq!(
                direct.data,
                planned.data,
                "{}/{}: planned back differs from direct",
                model.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn adjoint_identity_holds_through_plan() {
    let mut rng = Rng::new(303);
    // exact only on the f32 storage tier: a reduced tier's Aᵀ reads a
    // quantized sinogram, so under a 16-bit LEAP_STORAGE default the
    // identity holds to the tier's accuracy class (docs/MEMORY.md)
    let tol = if leap::precision::default_tier() == leap::StorageTier::F32 { 5e-5 } else { 5e-3 };
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(2);
            let plan = p.plan();
            let mut x = p.new_vol();
            let mut y = p.new_sino();
            rng.fill_uniform(&mut x.data, -1.0, 1.0);
            rng.fill_uniform(&mut y.data, -1.0, 1.0);
            let ax = plan.forward(&x);
            let aty = plan.back(&y);
            let lhs = dot_f64(&ax.data, &y.data);
            let rhs = dot_f64(&x.data, &aty.data);
            let gap = (lhs - rhs).abs() / lhs.abs().max(rhs.abs()).max(1e-12);
            assert!(
                gap < tol,
                "{}/{}: adjoint gap through plan {gap}",
                model.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn plan_reuse_is_deterministic_across_applications() {
    // applying the same plan many times (the iterative-solver pattern)
    // must give the same floats every time
    let vg = VolumeGeometry::cube(10, 1.0);
    let g = Geometry::Cone(ConeBeam::standard(8, 8, 12, 1.4, 1.4, 70.0, 140.0));
    let p = Projector::new(g, vg, Model::SF).with_threads(4);
    let plan = p.plan();
    let mut rng = Rng::new(404);
    let mut x = p.new_vol();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    let first = plan.forward(&x);
    for _ in 0..5 {
        let again = plan.forward(&x);
        assert_eq!(first.data, again.data);
    }
    let back_first = plan.back(&first);
    for _ in 0..5 {
        let again = plan.back(&first);
        assert_eq!(back_first.data, again.data);
    }
}

#[test]
fn solvers_match_their_planless_equivalents() {
    // sirt() plans internally; a hand-rolled loop over the direct path
    // must produce the identical volume (plan ≡ direct, end to end)
    let vg = VolumeGeometry::slice2d(24, 24, 1.0);
    let g = Geometry::Parallel(ParallelBeam::standard_2d(16, 36, 1.0));
    let p = Projector::new(g, vg.clone(), Model::SF).with_threads(2);
    let truth = leap::phantom::shepp::shepp_logan_2d(10.0, 0.02).rasterize(&vg, 2);
    let y = p.forward(&truth);

    let opts = leap::recon::SirtOpts { iterations: 8, ..Default::default() };
    let via_plan = leap::recon::sirt(&p, &y, &p.new_vol(), &opts).vol;

    // the pre-plan SIRT loop, application-by-application on the direct path
    let row_sum = p.forward_ones();
    let mut col_ones = p.new_sino();
    col_ones.fill(1.0);
    let col_sum = p.back(&col_ones);
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut x = p.new_vol();
    let mut ax = p.new_sino();
    let mut grad = p.new_vol();
    for _ in 0..opts.iterations {
        p.forward_into(&x, &mut ax);
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        p.back_into(&ax, &mut grad);
        for i in 0..x.len() {
            let v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
            x.data[i] = if v < 0.0 { 0.0 } else { v };
        }
    }
    assert_eq!(via_plan.data, x.data, "planned SIRT deviates from the direct-path loop");
}
