//! Property tests for the reduced-precision storage tiers
//! (`leap::precision`).
//!
//! The tier contract (docs/MEMORY.md) mirrors the backend contract two
//! doors down:
//!
//! * **Within** a tier, results are bit-identical across thread counts
//!   and across the planned/direct split — quantization is a pure
//!   per-element map on data at rest (cached cone coefficient tables,
//!   backprojection sinogram input), never on the accumulation, so the
//!   slab/unit ownership invariants are untouched.
//! * **Across** tiers, forward and back projections track the f32 tier
//!   to a relative-l2 tolerance set by the storage format's mantissa
//!   (f16: 11 bits, bf16: 8 bits) — and the models/geometries whose
//!   paths store nothing (parallel/fan SF forward) agree *exactly*.
//!
//! Both properties sweep every model × every geometry family, plus the
//! builder/env selection story end-to-end.

use leap::geometry::config::ScanConfig;
use leap::geometry::{
    ConeBeam, DetectorShape, FanBeam, Geometry, ModularBeam, ParallelBeam, VolumeGeometry,
};
use leap::projector::{Model, Projector};
use leap::util::rng::Rng;
use leap::{LeapError, ScanBuilder, StorageTier};

/// One geometry per family (flat and curved cone detectors both count:
/// they take different footprint/ray code paths).
fn all_geometries() -> Vec<Geometry> {
    let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
    let mut curved = cone.clone();
    curved.shape = DetectorShape::Curved;
    vec![
        Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
        Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
    ]
}

fn vg_for(geom: &Geometry) -> VolumeGeometry {
    if matches!(geom, Geometry::Fan(_)) {
        VolumeGeometry::slice2d(12, 12, 1.0)
    } else {
        VolumeGeometry::cube(10, 1.0)
    }
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - y as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

const REDUCED: [StorageTier; 2] = [StorageTier::F16, StorageTier::Bf16];

/// The acceptance bound: reduced-tier projections track f32 to 2e-3
/// relative l2. bf16 keeps 8 mantissa bits, so round-to-nearest
/// quantization of a stored element is bounded by ~2⁻⁹ ≈ 1.95e-3 of
/// its magnitude (mean ~1.5e-3 over a uniform mantissa). Projection
/// outputs sum many independently-rounded terms and usually average
/// well below that, but small projections (few coefficients per ray)
/// can sit near the per-element bound — so the gate is the bound
/// itself, not the averaged behaviour (f16, with 3 more mantissa
/// bits, sits ~8× lower still).
const TIER_TOL: f64 = 2e-3;

#[test]
fn reduced_tiers_track_f32_within_tolerance_all_models_all_geometries() {
    let mut rng = Rng::new(801);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let f32p = Projector::new(geom.clone(), vg.clone(), model)
                .with_threads(3)
                .with_storage_tier(StorageTier::F32);
            let mut x = f32p.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            let mut y = f32p.new_sino();
            rng.fill_uniform(&mut y.data, 0.0, 1.0);
            let fwd_ref = f32p.forward(&x);
            let back_ref = f32p.back(&y);
            for tier in REDUCED {
                let p = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(3)
                    .with_storage_tier(tier);
                let fwd_gap = rel_l2(&p.forward(&x).data, &fwd_ref.data);
                assert!(
                    fwd_gap <= TIER_TOL,
                    "{}/{}/{}: forward tier gap {fwd_gap}",
                    tier.name(),
                    model.name(),
                    p.geom.kind()
                );
                let back_gap = rel_l2(&p.back(&y).data, &back_ref.data);
                assert!(
                    back_gap <= TIER_TOL,
                    "{}/{}/{}: back tier gap {back_gap}",
                    tier.name(),
                    model.name(),
                    p.geom.kind()
                );
            }
        }
    }
}

#[test]
fn forward_paths_without_stored_tables_are_exact_across_tiers() {
    // parallel-beam SF stores no per-view coefficient table and the
    // forward path quantizes no input, so its "quantized" tiers are the
    // f32 tier bit for bit — the accuracy-class table of docs/MEMORY.md
    let mut rng = Rng::new(802);
    let geom = Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3));
    let vg = vg_for(&geom);
    let f32p = Projector::new(geom.clone(), vg.clone(), Model::SF)
        .with_threads(2)
        .with_storage_tier(StorageTier::F32);
    let mut x = f32p.new_vol();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    let reference = f32p.forward(&x);
    for tier in REDUCED {
        let p = Projector::new(geom.clone(), vg.clone(), Model::SF)
            .with_threads(2)
            .with_storage_tier(tier);
        assert_eq!(
            p.forward(&x).data,
            reference.data,
            "{}: parallel SF forward must not depend on the storage tier",
            tier.name()
        );
    }
}

#[test]
fn each_tier_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(803);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for tier in [StorageTier::F32, StorageTier::F16, StorageTier::Bf16] {
                let single = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(1)
                    .with_storage_tier(tier);
                let multi = Projector::new(geom.clone(), vg.clone(), model)
                    .with_threads(3)
                    .with_storage_tier(tier);
                let mut x = single.new_vol();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                assert_eq!(
                    single.forward(&x).data,
                    multi.forward(&x).data,
                    "{}/{}/{}: forward depends on thread count",
                    tier.name(),
                    model.name(),
                    single.geom.kind()
                );
                let mut y = single.new_sino();
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                assert_eq!(
                    single.back(&y).data,
                    multi.back(&y).data,
                    "{}/{}/{}: back depends on thread count",
                    tier.name(),
                    model.name(),
                    single.geom.kind()
                );
            }
        }
    }
}

#[test]
fn planned_and_direct_paths_agree_per_tier() {
    // the plan/execute-split invariant must survive tier selection: a
    // cached plan (packed coefficient arenas) and the direct path
    // (transient plan, quantized scratch) produce the same bits,
    // because pack() and quantize_in_place() emit the identical
    // coefficient stream (decode(encode(x)) == quantize(x))
    let mut rng = Rng::new(804);
    for geom in all_geometries() {
        let vg = vg_for(&geom);
        for tier in REDUCED {
            let p = Projector::new(geom.clone(), vg.clone(), Model::SF)
                .with_threads(3)
                .with_storage_tier(tier);
            let plan = p.plan();
            assert_eq!(plan.storage(), tier, "plan must snapshot its projector's tier");
            let mut x = p.new_vol();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            let direct = p.forward(&x);
            let mut planned = p.new_sino();
            plan.forward_into(&x, &mut planned);
            assert_eq!(
                direct.data,
                planned.data,
                "{}/{}: planned forward differs from direct",
                tier.name(),
                p.geom.kind()
            );
            let mut y = p.new_sino();
            rng.fill_uniform(&mut y.data, 0.0, 1.0);
            let direct_back = p.back(&y);
            let mut planned_back = p.new_vol();
            plan.back_into(&y, &mut planned_back);
            assert_eq!(
                direct_back.data,
                planned_back.data,
                "{}/{}: planned back differs from direct",
                tier.name(),
                p.geom.kind()
            );
        }
    }
}

#[test]
fn builder_validates_storage_selection_end_to_end() {
    let cfg = ScanConfig {
        geometry: Geometry::Parallel(ParallelBeam::standard_2d(8, 16, 1.0)),
        volume: VolumeGeometry::slice2d(12, 12, 1.0),
    };
    for tier in [StorageTier::F32, StorageTier::F16, StorageTier::Bf16] {
        let scan = ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .storage_tier(tier)
            .build()
            .unwrap();
        assert_eq!(scan.storage_tier(), tier);
    }
    // the string knob parses leniently (case, surrounding whitespace)
    for (name, tier) in [
        ("f16", StorageTier::F16),
        (" BF16 ", StorageTier::Bf16),
        ("half", StorageTier::F16),
        ("float32", StorageTier::F32),
    ] {
        let scan = ScanBuilder::from_config(&cfg).storage_tier_str(name).build().unwrap();
        assert_eq!(scan.storage_tier(), tier, "{name:?}");
    }
    // typed knob beats string knob, matching the backend precedence
    let scan = ScanBuilder::from_config(&cfg)
        .storage_tier_str("bf16")
        .storage_tier(StorageTier::F16)
        .build()
        .unwrap();
    assert_eq!(scan.storage_tier(), StorageTier::F16);
    // unknown names are a typed InvalidArgument at build time
    let e = ScanBuilder::from_config(&cfg).storage_tier_str("f8").build().unwrap_err();
    assert!(matches!(e, LeapError::InvalidArgument(ref m) if m.contains("f8")), "{e:?}");
}

#[test]
fn reduced_tier_scans_solve_close_to_the_f32_tier() {
    // end-to-end: an iterative reconstruction run entirely on the f16
    // tier lands near the f32 tier (per-iteration tier error does not
    // amplify — the pair stays matched per tier, so SIRT still descends)
    let cfg = ScanConfig {
        geometry: Geometry::Parallel(ParallelBeam::standard_2d(16, 36, 1.0)),
        volume: VolumeGeometry::slice2d(24, 24, 1.0),
    };
    let truth = leap::phantom::shepp::shepp_logan_2d(10.0, 0.02).rasterize(&cfg.volume, 2);
    let mut recon = Vec::new();
    for tier in [StorageTier::F32, StorageTier::F16] {
        let scan = ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .storage_tier(tier)
            .build()
            .unwrap();
        let sino = scan.forward(&truth.data).unwrap();
        let solver = leap::Solver::Sirt { iterations: 8, lambda: 1.0, nonneg: true };
        recon.push(scan.solve(solver, &sino).unwrap());
    }
    let gap = rel_l2(&recon[1], &recon[0]);
    assert!(gap <= 5e-3, "SIRT cross-tier gap {gap}");
}

#[test]
fn tiered_sino_round_trip_preserves_shape_and_tolerance() {
    use leap::precision::TieredSino;
    let mut rng = Rng::new(805);
    let p = Projector::new(
        Geometry::Parallel(ParallelBeam::standard_3d(5, 6, 9, 1.0, 1.0)),
        VolumeGeometry::cube(6, 1.0),
        Model::SF,
    );
    let mut y = p.new_sino();
    rng.fill_uniform(&mut y.data, 0.0, 1.0);
    for tier in [StorageTier::F32, StorageTier::F16, StorageTier::Bf16] {
        let t = TieredSino::from_sino(tier, &y);
        let back = t.to_sino();
        assert_eq!((back.nviews, back.nrows, back.ncols), (y.nviews, y.nrows, y.ncols));
        assert_eq!(back.data.len(), y.data.len());
        let gap = rel_l2(&back.data, &y.data);
        let bound = match tier {
            StorageTier::F32 => 0.0,
            StorageTier::F16 => 5e-4,
            StorageTier::Bf16 => 4e-3,
        };
        assert!(gap <= bound, "{}: round-trip gap {gap}", tier.name());
        // storage really shrinks: the tiered copy holds tier-width bits
        assert_eq!(t.storage_bytes(), y.data.len() * tier.bytes_per_sample());
        // quantization is idempotent: a second trip is the identity
        let twice = TieredSino::from_sino(&back, tier).to_sino();
        assert_eq!(twice.data, back.data, "{}: quantize must be idempotent", tier.name());
    }
}
