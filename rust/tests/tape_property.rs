//! Tape-layer properties: central finite-difference gradient checks for
//! **every node type** (rel err ≤ 1e-3), for a full K=3 unrolled
//! pipeline over all of its parameters, and bit-determinism of `fit()`.
//!
//! Methodology: for a pipeline with parameters `p` and a random
//! direction `d` (one block per parameter), compare the analytic
//! directional derivative `Σ ⟨∇_p L, d_p⟩` against the central
//! difference `(L(p + h·d) − L(p − h·d)) / 2h`. Loss values are f64 at
//! the loss node, so FD noise sits well below the 1e-3 gate as long as
//! the pipeline is smooth at `p` — tests place values away from
//! relu/clamp kinks by a margin ≫ h.

use std::sync::Arc;

use leap::api::ScanBuilder;
use leap::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::ops::{LinearOp, PlanOp, Shape};
use leap::projector::{Model, Projector};
use leap::recon::filters::ramp_half_spectrum;
use leap::recon::Window;
use leap::tape::{
    fit, fit_batched, learned_fbp, unrolled_cnn, unrolled_gd, BatchFitCfg, FitCfg, Fitter,
    Optimizer, Pipeline, PipelineBuilder, UnrollCfg, UnrollCnnCfg,
};
use leap::util::rng::Rng;
use leap::StorageTier;

const FD_TOL: f64 = 1e-3;
const H: f32 = 1e-2;

// The FD ops pin the f32 storage tier: central differences probe the
// true (smooth) operator, and a reduced tier's Aᵀ reads its input
// through a quantization staircase whose step is comparable to the FD
// step H — tier accuracy has its own suite (storage_property.rs).
fn fan_op() -> Arc<dyn LinearOp> {
    let vg = VolumeGeometry::slice2d(10, 10, 1.0);
    let g = Geometry::Fan(FanBeam::standard(8, 14, 1.0, 60.0, 120.0));
    Arc::new(PlanOp::new(
        &Projector::new(g, vg, Model::SF).with_threads(2).with_storage_tier(StorageTier::F32),
    ))
}

fn parallel_op() -> Arc<dyn LinearOp> {
    let vg = VolumeGeometry::slice2d(10, 10, 1.0);
    let g = Geometry::Parallel(ParallelBeam::standard_2d(7, 16, 1.0));
    Arc::new(PlanOp::new(
        &Projector::new(g, vg, Model::SF).with_threads(2).with_storage_tier(StorageTier::F32),
    ))
}

fn rand_vec(n: usize, lo: f64, hi: f64, rng: &mut Rng) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_uniform(&mut v, lo, hi);
    v
}

/// Central FD check of `Σ ⟨∇_p L, d_p⟩` over every parameter at once.
/// Returns the relative gap.
fn fd_gap(pipe: &Pipeline, inputs: &[&[f32]], seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let params: Vec<Vec<f32>> = pipe.params().iter().map(|p| p.value.clone()).collect();
    let dirs: Vec<Vec<f32>> = pipe
        .params()
        .iter()
        .map(|p| rand_vec(p.shape.numel(), -1.0, 1.0, &mut rng))
        .collect();
    let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let (_, grads) = pipe.loss_and_grads_with(&pr, inputs).unwrap();
    let analytic: f64 = grads
        .iter()
        .zip(dirs.iter())
        .flat_map(|(g, d)| g.iter().zip(d.iter()))
        .map(|(&g, &d)| g as f64 * d as f64)
        .sum();
    let shifted = |sign: f32| -> f64 {
        let moved: Vec<Vec<f32>> = params
            .iter()
            .zip(dirs.iter())
            .map(|(p, d)| p.iter().zip(d.iter()).map(|(&a, &b)| a + sign * H * b).collect())
            .collect();
        let mr: Vec<&[f32]> = moved.iter().map(|v| v.as_slice()).collect();
        pipe.loss_with(&mr, inputs).unwrap()
    };
    let fd = (shifted(1.0) - shifted(-1.0)) / (2.0 * H as f64);
    (analytic - fd).abs() / analytic.abs().max(fd.abs()).max(1e-9)
}

fn assert_fd(pipe: &Pipeline, inputs: &[&[f32]], seed: u64, what: &str) {
    let gap = fd_gap(pipe, inputs, seed);
    assert!(gap <= FD_TOL, "{what}: fd gap {gap} > {FD_TOL}");
}

// ── per-node finite-difference checks ────────────────────────────────────

#[test]
fn fd_apply_node() {
    // L = ½‖A·p − b‖² : exercises Apply forward + its Aᵀ VJP
    let a = fan_op();
    let mut rng = Rng::new(1);
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a.clone()).unwrap();
    let init = rand_vec(a.domain_shape().numel(), 0.2, 1.0, &mut rng);
    let p = pb.param("x", a.domain_shape(), init).unwrap();
    let b = pb.input(a.range_shape()).unwrap();
    let ax = pb.apply(op, p).unwrap();
    let l = pb.l2_loss(ax, b).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let data = rand_vec(a.range_shape().numel(), 0.2, 1.0, &mut rng);
    assert_fd(&pipe, &[&data], 100, "apply");
}

#[test]
fn fd_adjoint_node() {
    // L = ½‖Aᵀ·q − t‖² : exercises Adjoint forward + its A VJP
    let a = fan_op();
    let mut rng = Rng::new(2);
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a.clone()).unwrap();
    let init = rand_vec(a.range_shape().numel(), 0.2, 1.0, &mut rng);
    let q = pb.param("q", a.range_shape(), init).unwrap();
    let t = pb.input(a.domain_shape()).unwrap();
    let bp = pb.adjoint(op, q).unwrap();
    let l = pb.l2_loss(bp, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let data = rand_vec(a.domain_shape().numel(), 0.2, 1.0, &mut rng);
    assert_fd(&pipe, &[&data], 101, "adjoint");
}

#[test]
fn fd_add_sub_mul_scale_nodes() {
    // L = ½‖(p ⊙ q + p − q)·s − b‖² : Add, Sub, Mul and both Scale VJPs
    let mut rng = Rng::new(3);
    let n = 40;
    let shape = Shape([n, 1, 1]);
    let mut pb = PipelineBuilder::new();
    let p = pb.param("p", shape, rand_vec(n, 0.2, 1.0, &mut rng)).unwrap();
    let q = pb.param("q", shape, rand_vec(n, 0.2, 1.0, &mut rng)).unwrap();
    let s = pb.scalar_param("s", 0.7).unwrap();
    let b = pb.input(shape).unwrap();
    let pq = pb.mul(p, q).unwrap();
    let sum = pb.add(pq, p).unwrap();
    let diff = pb.sub(sum, q).unwrap();
    let scaled = pb.scale(diff, s).unwrap();
    let l = pb.l2_loss(scaled, b).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let data = rand_vec(n, 0.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&data], 102, "add/sub/mul/scale");
}

#[test]
fn fd_relu_node() {
    // relu(p − b) with b ∈ {0, 1} and p ∈ [0.4, 0.6]: every element is
    // ≥ 0.4 away from the kink, far beyond the FD step
    let mut rng = Rng::new(4);
    let n = 30;
    let shape = Shape([n, 1, 1]);
    let mut pb = PipelineBuilder::new();
    let p = pb.param("p", shape, rand_vec(n, 0.4, 0.6, &mut rng)).unwrap();
    let b = pb.input(shape).unwrap();
    let t = pb.input(shape).unwrap();
    let pre = pb.sub(p, b).unwrap();
    let act = pb.relu(pre).unwrap();
    let l = pb.l2_loss(act, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let offsets: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 0.0 } else { 1.0 }).collect();
    let target = rand_vec(n, 0.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&offsets, &target], 103, "relu");
    // and the masked half really is masked: gradient there must be zero
    let params: Vec<&[f32]> = pipe.params().iter().map(|p| p.value.as_slice()).collect();
    let (_, grads) = pipe
        .loss_and_grads_with(&params, &[&offsets, &target])
        .unwrap();
    for (i, &g) in grads[0].iter().enumerate() {
        if i % 2 == 1 {
            assert_eq!(g, 0.0, "element {i} is clamped negative; gradient must not flow");
        }
    }
}

#[test]
fn fd_clamp_node() {
    // clamp(p, 0.25, 0.75) with p ∈ {0.1, 0.5, 0.9}: every element sits
    // 0.15 from the nearest edge
    let n = 30;
    let shape = Shape([n, 1, 1]);
    let mut rng = Rng::new(5);
    let init: Vec<f32> = (0..n).map(|i| [0.1f32, 0.5, 0.9][i % 3]).collect();
    let mut pb = PipelineBuilder::new();
    let p = pb.param("p", shape, init).unwrap();
    let t = pb.input(shape).unwrap();
    let c = pb.clamp(p, 0.25, 0.75).unwrap();
    let l = pb.l2_loss(c, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(n, 0.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 104, "clamp");
    let params: Vec<&[f32]> = pipe.params().iter().map(|p| p.value.as_slice()).collect();
    let (_, grads) = pipe.loss_and_grads_with(&params, &[&target]).unwrap();
    for (i, &g) in grads[0].iter().enumerate() {
        if i % 3 != 1 {
            assert_eq!(g, 0.0, "element {i} is clamped; gradient must not flow");
        }
    }
}

#[test]
fn fd_filter_rows_node_both_paths() {
    // L = ½‖filter_w(p) − t‖² with BOTH the rows (p) and the
    // half-spectrum (w) trainable: the self-adjoint dx path and the
    // FFT-domain dw path in one check
    let nviews = 6;
    let ncols = 16;
    let shape = Shape([nviews, 1, ncols]);
    let mut rng = Rng::new(6);
    let mut pb = PipelineBuilder::new();
    let p = pb
        .param("rows", shape, rand_vec(shape.numel(), -1.0, 1.0, &mut rng))
        .unwrap();
    let half = ramp_half_spectrum(ncols, 1.0, Window::Hann);
    let w = pb.param("w", Shape([half.len(), 1, 1]), half).unwrap();
    let t = pb.input(shape).unwrap();
    let f = pb.filter_rows(p, w).unwrap();
    let l = pb.l2_loss(f, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(shape.numel(), -1.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 105, "filter_rows");
}

#[test]
fn fd_l2_loss_target_path() {
    // the target side of L2Loss is differentiable too (−residual)
    let n = 25;
    let shape = Shape([n, 1, 1]);
    let mut rng = Rng::new(7);
    let mut pb = PipelineBuilder::new();
    let t = pb.param("t", shape, rand_vec(n, 0.2, 1.0, &mut rng)).unwrap();
    let pred = pb.input(shape).unwrap();
    let l = pb.l2_loss(pred, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let data = rand_vec(n, 0.2, 1.0, &mut rng);
    assert_fd(&pipe, &[&data], 106, "l2 target");
}

#[test]
fn fd_poisson_loss_both_paths() {
    // pred strictly positive (≥ 0.2, far above the ε clamp) so the NLL
    // is smooth; check pred-as-param and target-as-param separately
    let n = 25;
    let shape = Shape([n, 1, 1]);
    let mut rng = Rng::new(8);

    let mut pb = PipelineBuilder::new();
    let p = pb.param("pred", shape, rand_vec(n, 0.2, 1.0, &mut rng)).unwrap();
    let b = pb.input(shape).unwrap();
    let l = pb.poisson_loss(p, b).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let counts = rand_vec(n, 0.0, 2.0, &mut rng);
    assert_fd(&pipe, &[&counts], 107, "poisson pred");

    let mut pb = PipelineBuilder::new();
    let t = pb.param("t", shape, rand_vec(n, 0.1, 2.0, &mut rng)).unwrap();
    let pred = pb.input(shape).unwrap();
    let l = pb.poisson_loss(pred, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let preds = rand_vec(n, 0.2, 1.0, &mut rng);
    assert_fd(&pipe, &[&preds], 108, "poisson target");
}

// ── neural nodes ─────────────────────────────────────────────────────────

#[test]
fn fd_conv2d_node_all_three_paths() {
    // L = ½‖conv2d(x, w, b) − t‖² with x, w AND b trainable: one FD
    // check covers the input, weight and bias VJPs of a multi-channel
    // (cin=2 → cout=3) kernel
    let (wd, ht, cin, cout, k) = (6, 5, 2, 3, 3);
    let mut rng = Rng::new(20);
    let mut pb = PipelineBuilder::new();
    let x = pb
        .param("x", Shape([wd, ht, cin]), rand_vec(wd * ht * cin, -1.0, 1.0, &mut rng))
        .unwrap();
    let w = pb
        .param("w", Shape([k * k, cin, cout]), rand_vec(k * k * cin * cout, -0.5, 0.5, &mut rng))
        .unwrap();
    let b = pb.param("b", Shape([cout, 1, 1]), rand_vec(cout, -0.5, 0.5, &mut rng)).unwrap();
    let t = pb.input(Shape([wd, ht, cout])).unwrap();
    let c = pb.conv2d(x, w, b).unwrap();
    let l = pb.l2_loss(c, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(wd * ht * cout, -1.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 120, "conv2d x/w/b");
}

#[test]
fn fd_conv3d_node_all_three_paths() {
    // volume [5, 4, cin·nz] with cin=2, nz=3: the z-extent of the
    // kernel and the channel blocking both exercised
    let (wd, ht, nz, cin, cout, k) = (5, 4, 3, 2, 2, 3);
    let slabs = cin * nz;
    let mut rng = Rng::new(21);
    let mut pb = PipelineBuilder::new();
    let x = pb
        .param("x", Shape([wd, ht, slabs]), rand_vec(wd * ht * slabs, -1.0, 1.0, &mut rng))
        .unwrap();
    let w = pb
        .param(
            "w",
            Shape([k * k * k, cin, cout]),
            rand_vec(k * k * k * cin * cout, -0.3, 0.3, &mut rng),
        )
        .unwrap();
    let b = pb.param("b", Shape([cout, 1, 1]), rand_vec(cout, -0.5, 0.5, &mut rng)).unwrap();
    let t = pb.input(Shape([wd, ht, cout * nz])).unwrap();
    let c = pb.conv3d(x, w, b, cin).unwrap();
    let l = pb.l2_loss(c, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(wd * ht * cout * nz, -1.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 121, "conv3d x/w/b");
}

#[test]
fn fd_avg_pool_upsample_and_residual_nodes() {
    // L = ½‖x + upsample(avg_pool(x)) − t‖²: pool and upsample VJPs
    // (exact adjoints of each other) plus the Residual add, in one pass
    let (wd, ht, c, f) = (8, 6, 2, 2);
    let mut rng = Rng::new(22);
    let mut pb = PipelineBuilder::new();
    let x = pb
        .param("x", Shape([wd, ht, c]), rand_vec(wd * ht * c, -1.0, 1.0, &mut rng))
        .unwrap();
    let t = pb.input(Shape([wd, ht, c])).unwrap();
    let pooled = pb.avg_pool(x, f).unwrap();
    let up = pb.upsample(pooled, f).unwrap();
    let r = pb.residual(x, up).unwrap();
    let l = pb.l2_loss(r, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(wd * ht * c, -1.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 122, "avg_pool/upsample/residual");
}

#[test]
fn fd_cnn_block_matches_the_unrolled_cnn_shape() {
    // the exact conv→relu→conv residual chain unrolled_cnn builds,
    // placed FD-safely: x ∈ [0.4, 0.6], small weights, bias 0.5 pushes
    // every hidden activation ≥ ~0.2 from the relu kink (FD moves
    // activations by ≤ ~0.07)
    let (wd, ht, c, k) = (8, 6, 3, 3);
    let mut rng = Rng::new(23);
    let mut pb = PipelineBuilder::new();
    let x = pb.param("x", Shape([wd, ht, 1]), rand_vec(wd * ht, 0.4, 0.6, &mut rng)).unwrap();
    let w1 = pb
        .param("w1", Shape([k * k, 1, c]), rand_vec(k * k * c, -0.05, 0.05, &mut rng))
        .unwrap();
    let b1 = pb.param("b1", Shape([c, 1, 1]), vec![0.5f32; c]).unwrap();
    let w2 = pb
        .param("w2", Shape([k * k, c, 1]), rand_vec(k * k * c, -0.05, 0.05, &mut rng))
        .unwrap();
    let b2 = pb.param("b2", Shape([1, 1, 1]), vec![0.1f32]).unwrap();
    let t = pb.input(Shape([wd, ht, 1])).unwrap();
    let h = pb.conv2d(x, w1, b1).unwrap();
    let h = pb.relu(h).unwrap();
    let corr = pb.conv2d(h, w2, b2).unwrap();
    let r = pb.residual(x, corr).unwrap();
    let l = pb.l2_loss(r, t).unwrap();
    pb.set_loss(l).unwrap();
    let pipe = pb.build().unwrap();
    let target = rand_vec(wd * ht, 0.0, 1.0, &mut rng);
    assert_fd(&pipe, &[&target], 123, "cnn block");
}

// ── mini-batch aggregation and checkpointing ─────────────────────────────

#[test]
fn batched_grads_are_bit_identical_to_sequential_accumulation() {
    // loss_and_grads_batch must equal the sequential in-order
    // reduction (f64 loss sum, f32 axpy, one 1/n scale) bit for bit,
    // at every thread count
    let a = fan_op();
    let pipe =
        unrolled_gd(a.clone(), &UnrollCfg { iterations: 2, step_init: 0.01, nonneg: true })
            .unwrap();
    let mut rng = Rng::new(24);
    let params: Vec<Vec<f32>> =
        pipe.params().iter().map(|p| rand_vec(p.shape.numel(), 0.005, 0.02, &mut rng)).collect();
    let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let items: Vec<Vec<Vec<f32>>> = (0..5)
        .map(|_| {
            pipe.input_shapes()
                .iter()
                .map(|s| rand_vec(s.numel(), 0.0, 1.0, &mut rng))
                .collect()
        })
        .collect();
    let ir: Vec<Vec<&[f32]>> =
        items.iter().map(|it| it.iter().map(|b| b.as_slice()).collect()).collect();

    // sequential reference: the exact reduction the batch path promises
    let mut loss_sum = 0.0f64;
    let mut want: Vec<Vec<f32>> =
        pipe.params().iter().map(|p| vec![0.0f32; p.shape.numel()]).collect();
    for it in &ir {
        let (l, gs) = pipe.loss_and_grads_with(&pr, it).unwrap();
        loss_sum += l;
        for (acc, g) in want.iter_mut().zip(gs.iter()) {
            for (av, &gv) in acc.iter_mut().zip(g.iter()) {
                *av += gv;
            }
        }
    }
    let inv = 1.0f32 / ir.len() as f32;
    for g in &mut want {
        for v in g.iter_mut() {
            *v *= inv;
        }
    }
    let want_loss = loss_sum / ir.len() as f64;

    for threads in [1, 2, 3, 8] {
        let (loss, grads) = pipe.loss_and_grads_batch(&pr, &ir, threads).unwrap();
        assert_eq!(loss.to_bits(), want_loss.to_bits(), "threads {threads}: loss");
        for (pi, (g, w)) in grads.iter().zip(want.iter()).enumerate() {
            let gb: Vec<u32> = g.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "threads {threads}: param {pi} grads");
        }
    }
}

#[test]
fn checkpointed_cnn_training_resumes_bit_identically() {
    // the end-to-end resume property on the REAL pipeline shape: train
    // the unrolled CNN solver, checkpoint at the midpoint, restore into
    // a freshly built pipeline, finish — bit-identical to uninterrupted
    let a = fan_op();
    let cfg = UnrollCnnCfg { iterations: 1, step_init: 0.01, channels: 2, ksize: 3, seed: 5 };
    let opt = Optimizer::adam(0.002);
    let mut rng = Rng::new(25);
    let mut truth = vec![0.0f32; a.domain_shape().numel()];
    rng.fill_uniform(&mut truth, 0.1, 1.0);
    let sino = a.apply(&truth);
    let items = vec![vec![sino.clone(), truth.clone()]];
    let bcfg = |epochs: usize| BatchFitCfg { optimizer: opt, epochs, batch_size: 1, threads: 2 };

    // uninterrupted: one fitter, 8 steps
    let mut pipe_a = unrolled_cnn(a.clone(), &cfg).unwrap();
    let mut fit_a = Fitter::new(&pipe_a, opt).unwrap();
    for _ in 0..8 {
        let pr: Vec<&[f32]> = pipe_a.params().iter().map(|p| p.value.as_slice()).collect();
        let (_, g) = pipe_a
            .loss_and_grads_batch(&pr, &[vec![sino.as_slice(), truth.as_slice()]], 2)
            .unwrap();
        fit_a.step(&mut pipe_a, &g).unwrap();
    }

    // interrupted: 4 steps, save, restore into a FRESH pipeline+fitter,
    // 4 more
    let mut pipe_b = unrolled_cnn(a.clone(), &cfg).unwrap();
    let mut fit_b = Fitter::new(&pipe_b, opt).unwrap();
    for _ in 0..4 {
        let pr: Vec<&[f32]> = pipe_b.params().iter().map(|p| p.value.as_slice()).collect();
        let (_, g) = pipe_b
            .loss_and_grads_batch(&pr, &[vec![sino.as_slice(), truth.as_slice()]], 2)
            .unwrap();
        fit_b.step(&mut pipe_b, &g).unwrap();
    }
    let ckpt = fit_b.save(&pipe_b);
    let mut pipe_c = unrolled_cnn(a.clone(), &cfg).unwrap();
    let mut fit_c = Fitter::new(&pipe_c, opt).unwrap();
    fit_c.restore(&mut pipe_c, &ckpt).unwrap();
    for _ in 0..4 {
        let pr: Vec<&[f32]> = pipe_c.params().iter().map(|p| p.value.as_slice()).collect();
        let (_, g) = pipe_c
            .loss_and_grads_batch(&pr, &[vec![sino.as_slice(), truth.as_slice()]], 2)
            .unwrap();
        fit_c.step(&mut pipe_c, &g).unwrap();
    }

    for (pa, pc) in pipe_a.params().iter().zip(pipe_c.params().iter()) {
        let ba: Vec<u32> = pa.value.iter().map(|v| v.to_bits()).collect();
        let bc: Vec<u32> = pc.value.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bc, "param {} must resume bit-identically", pa.name);
    }

    // and fit_batched over the same items is deterministic run-to-run
    let run = || {
        let mut p = unrolled_cnn(a.clone(), &cfg).unwrap();
        fit_batched(&mut p, &items, &bcfg(6)).unwrap();
        let bits: Vec<Vec<u32>> =
            p.params().iter().map(|q| q.value.iter().map(|v| v.to_bits()).collect()).collect();
        bits
    };
    assert_eq!(run(), run(), "fit_batched must be bit-deterministic");
}

// ── whole-pipeline checks ────────────────────────────────────────────────

#[test]
fn fd_k3_unrolled_pipeline_all_params() {
    // the acceptance pipeline: K=3 unrolled GD, FD over all three
    // learnable steps at once (smooth variant — relu off — so the FD
    // probe cannot cross activation kinks)
    let a = fan_op();
    let pipe =
        unrolled_gd(a.clone(), &UnrollCfg { iterations: 3, step_init: 0.01, nonneg: false })
            .unwrap();
    let mut rng = Rng::new(9);
    let truth = rand_vec(a.domain_shape().numel(), 0.1, 1.0, &mut rng);
    let sino = a.apply(&truth);
    assert_fd(&pipe, &[&sino, &truth], 109, "K=3 unrolled gd");
}

#[test]
fn fd_learned_fbp_all_params() {
    // filter + per-sample weights + gain, through Aᵀ, in one directional
    // check
    let a = parallel_op();
    let pipe = learned_fbp(a.clone(), 1.0, Window::Hann).unwrap();
    let mut rng = Rng::new(10);
    let truth = rand_vec(a.domain_shape().numel(), 0.1, 1.0, &mut rng);
    let sino = a.apply(&truth);
    assert_fd(&pipe, &[&sino, &truth], 110, "learned fbp");
}

#[test]
fn two_identical_fits_produce_bit_identical_params() {
    // the determinism acceptance: same pipeline, same data, same
    // optimizer → every trained parameter bit-identical, run to run
    let run = || {
        let a = fan_op();
        let mut pipe =
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 3, step_init: 0.01, nonneg: true })
                .unwrap();
        let mut rng = Rng::new(11);
        let mut truth = vec![0.0f32; a.domain_shape().numel()];
        rng.fill_uniform(&mut truth, 0.1, 1.0);
        let sino = a.apply(&truth);
        let report = fit(
            &mut pipe,
            &[&sino, &truth],
            &FitCfg { optimizer: Optimizer::adam(0.005), iterations: 15 },
        )
        .unwrap();
        let params: Vec<Vec<u32>> = pipe
            .params()
            .iter()
            .map(|p| p.value.iter().map(|v| v.to_bits()).collect())
            .collect();
        let losses: Vec<u64> = report.losses.iter().map(|l| l.to_bits()).collect();
        (params, losses)
    };
    let (p1, l1) = run();
    let (p2, l2) = run();
    assert_eq!(p1, p2, "trained params must be bit-identical");
    assert_eq!(l1, l2, "loss trajectories must be bit-identical");
}

#[test]
fn trained_unroll_beats_its_untrained_initialization() {
    // end-to-end sanity on the api::Scan front door: fitting the K=3
    // unrolled pipeline must reduce the supervised loss it trains on
    let scan = ScanBuilder::new()
        .geometry(Geometry::Fan(FanBeam::standard(8, 14, 1.0, 60.0, 120.0)))
        .volume(VolumeGeometry::slice2d(10, 10, 1.0))
        .model(Model::SF)
        .threads(2)
        .build()
        .unwrap();
    let a: Arc<dyn LinearOp> = Arc::new(PlanOp::from_plan(scan.plan().clone()));
    let mut pipe =
        unrolled_gd(a, &UnrollCfg { iterations: 3, step_init: 0.005, nonneg: true }).unwrap();
    let mut rng = Rng::new(12);
    let mut truth = vec![0.0f32; scan.volume_len()];
    rng.fill_uniform(&mut truth, 0.1, 1.0);
    let sino = scan.forward(&truth).unwrap();
    let before = pipe.loss(&[&sino, &truth]).unwrap();
    let report = scan
        .fit(
            &mut pipe,
            &[&sino, &truth],
            &FitCfg { optimizer: Optimizer::adam(0.01), iterations: 30 },
        )
        .unwrap();
    assert!(
        report.final_loss < before,
        "training must improve on the initialization: {before} → {}",
        report.final_loss
    );
    // and the trained pipeline still evaluates (inference path)
    let recon = pipe.eval(&[&sino, &vec![0.0f32; scan.volume_len()]]).unwrap();
    assert_eq!(recon.len(), scan.volume_len());
}
