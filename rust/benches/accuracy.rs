//! Accuracy study (paper §2.1): "the DD and SF methods are more accurate
//! and other methods have been shown to produce artifacts in some cases."
//!
//! Compares Siddon / Joseph / SF forward projections of the *rasterized*
//! Shepp-Logan against the analytic sinogram of the continuous phantom
//! (no inverse crime), across resolutions and geometries, and measures
//! the reconstruction artifact level each model induces via matched SIRT.
//!
//! Run: `cargo bench --bench accuracy`

use leap::bench_harness::{append_results, Bench, Measurement};
use leap::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;

fn main() {
    let mut all: Vec<Measurement> = Vec::new();
    println!("── projector accuracy vs BIN-INTEGRATED analytic sinogram (rel-L2) ──");
    println!("(the physical detector averages over its bin; a point-sampled reference");
    println!(" would penalize SF for modeling exactly that — see phantom::project_binned)\n");
    for (n, nviews, ncols) in [(32usize, 24usize, 48usize), (64, 48, 96), (128, 90, 192)] {
        let vg = VolumeGeometry::slice2d(n, n, 128.0 / n as f64);
        let ph = shepp::shepp_logan_2d(52.0, 0.02);
        // supersampled rasterization: isolates projector error from
        // phantom discretization error
        let vol = ph.rasterize(&vg, 3);
        let g = ParallelBeam::standard_2d(nviews, ncols, 128.0 * 1.5 / ncols as f64);
        let analytic = ph.project_binned(&Geometry::Parallel(g.clone()), 8);
        print!("parallel {n}²/{nviews}: ");
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), model);
            let fp = p.forward(&vol);
            let rel = leap::util::rel_l2(&fp.data, &analytic.data, 1e-12);
            print!("{}={rel:.4}  ", model.name());
            let mut m = Measurement {
                name: format!("accuracy parallel {n} {}", model.name()),
                iters: 1,
                mean_s: 0.0,
                median_s: 0.0,
                p10_s: 0.0,
                p90_s: 0.0,
                notes: vec![("rel_l2".into(), rel)],
            };
            m.notes.push(("n".into(), n as f64));
            all.push(m);
        }
        println!();
    }

    // SF's defining property: for voxel-aligned piecewise-constant objects
    // the bin-integrated projection is *exact* (finite voxel × finite bin),
    // while point-sampling models (Siddon/Joseph) keep O(du) error.
    println!("\n── voxel-aligned box object, bin-integrated reference (SF exactness) ──");
    {
        let n = 64;
        let vg = VolumeGeometry::slice2d(n, n, 2.0);
        // boxes snapped to voxel boundaries (centers at odd mm)
        let ph = leap::phantom::Phantom::new(vec![
            leap::phantom::Shape::rect2d(0.0, 0.0, 24.0, 16.0, 0.0, 0.02),
            leap::phantom::Shape::rect2d(-20.0, 14.0, 8.0, 10.0, 0.0, 0.015),
        ]);
        let vol = ph.rasterize(&vg, 4);
        let g = ParallelBeam::standard_2d(40, 96, 2.0);
        let reference = ph.project_binned(&Geometry::Parallel(g.clone()), 16);
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), model);
            let fp = p.forward(&vol);
            let rel = leap::util::rel_l2(&fp.data, &reference.data, 1e-12);
            println!("  {}: rel {rel:.5}", model.name());
            all.push(Measurement {
                name: format!("accuracy box-aligned {}", model.name()),
                iters: 1,
                mean_s: 0.0,
                median_s: 0.0,
                p10_s: 0.0,
                p90_s: 0.0,
                notes: vec![("rel_l2".into(), rel)],
            });
        }
    }

    println!("\n── fan-beam accuracy (64²/60) ──");
    let vg = VolumeGeometry::slice2d(64, 64, 2.0);
    let ph = shepp::shepp_logan_2d(52.0, 0.02);
    let vol = ph.rasterize(&vg, 3);
    let g = FanBeam::standard(60, 128, 2.0, 256.0, 512.0);
    let analytic = ph.project_binned(&Geometry::Fan(g.clone()), 8);
    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(Geometry::Fan(g.clone()), vg.clone(), model);
        let fp = p.forward(&vol);
        let rel = leap::util::rel_l2(&fp.data, &analytic.data, 1e-12);
        println!("  {}: rel {rel:.4}", model.name());
        all.push(Measurement {
            name: format!("accuracy fan {}", model.name()),
            iters: 1,
            mean_s: 0.0,
            median_s: 0.0,
            p10_s: 0.0,
            p90_s: 0.0,
            notes: vec![("rel_l2".into(), rel)],
        });
    }

    // end-to-end artifact level: matched SIRT recon error per model
    println!("\n── recon error after SIRT×40 (RMSE vs truth) ──");
    let bench = Bench::quick();
    let vg = VolumeGeometry::slice2d(64, 64, 2.0);
    let truth = ph.rasterize(&vg, 2);
    let g = ParallelBeam::standard_2d(60, 96, 2.0);
    let sino = ph.project(&Geometry::Parallel(g.clone()));
    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), model);
        let r = recon::sirt(
            &p,
            &sino,
            &p.new_vol(),
            &recon::SirtOpts { iterations: 40, ..Default::default() },
        );
        let rmse = metrics::rmse(&r.vol.data, &truth.data);
        let psnr = metrics::psnr(&r.vol.data, &truth.data, None);
        println!("  {}: rmse {rmse:.6}  psnr {psnr:.2} dB", model.name());
        let mut m = bench.run(&format!("sirt40 {}", model.name()), || {
            recon::sirt(
                &p,
                &sino,
                &p.new_vol(),
                &recon::SirtOpts { iterations: 5, ..Default::default() },
            )
        });
        m.notes.push(("rmse".into(), rmse));
        all.push(m);
    }
    append_results(&all);
}
