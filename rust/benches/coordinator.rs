//! Coordinator overhead benchmarks: routing + batching + budget cost per
//! request, batching policy ablation, and served projection throughput on
//! the native backend. Target: coordinator overhead ≪ projection time
//! (DESIGN.md §7 — L3 must not be the bottleneck).
//!
//! Run: `cargo bench --bench coordinator`

use std::sync::Arc;
use std::time::Duration;

use leap::api::LeapError;
use leap::bench_harness::{append_results, Bench};
use leap::coordinator::{BatchPolicy, Coordinator, Executor, NativeExecutor, Op, Request, Router};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::projector::{Model, Projector};

/// Zero-work backend: isolates pure coordinator overhead.
struct NullExecutor;

impl Executor for NullExecutor {
    fn execute(&self, _op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
        Ok(vec![vec![inputs.len() as f32]])
    }
    fn ops(&self) -> Vec<Op> {
        vec![Op::Artifact("null".into())]
    }
}

fn main() {
    let bench = Bench::default();
    let mut all = Vec::new();

    // 1. pure dispatch overhead (null executor, no batching wait)
    let coord = Coordinator::new(
        Arc::new(NullExecutor),
        BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
        1 << 20,
        1,
    );
    let m = bench.run("dispatch overhead (null op, batch=1)", || {
        coord.call(Request::new(1, "null", vec![vec![0.0; 16]]))
    });
    let per_req_us = m.mean_s * 1e6;
    m.print();
    all.push(m);
    drop(coord);
    println!("    → {per_req_us:.1} µs per request of pure coordinator overhead\n");

    // 2. batching ablation on the native projector backend
    let vg = VolumeGeometry::slice2d(64, 64, 1.0);
    let g = ParallelBeam::standard_2d(90, 96, 1.0);
    let make_coord = |max_batch: usize, wait_ms: u64| {
        let exec: Arc<dyn Executor> = Arc::new(Router::new(vec![Arc::new(NativeExecutor::new(
            Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF),
        ))]));
        Arc::new(Coordinator::new(
            exec,
            BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) },
            1 << 30,
            2,
        ))
    };
    let vol = vec![0.01f32; vg.num_voxels()];
    for (max_batch, wait_ms, label) in
        [(1usize, 0u64, "no batching"), (8, 2, "batch≤8/2ms"), (16, 5, "batch≤16/5ms")]
    {
        let coord = make_coord(max_batch, wait_ms);
        let m = bench.run(&format!("serve 16×native_fp 64² [{label}]"), || {
            let rxs: Vec<_> = (0..16)
                .map(|i| coord.submit(Request::new(i, "native_fp", vec![vol.clone()])))
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
        });
        let mean_batch = coord
            .telemetry()
            .snapshot()
            .get("native_fp")
            .map(|s| s.mean_batch())
            .unwrap_or(0.0);
        let mut m = m;
        m.notes.push(("mean_batch".into(), mean_batch));
        m.print();
        all.push(m);
    }

    // 3. end-to-end projection throughput at several volume sizes
    println!();
    for n in [32usize, 64, 128] {
        let vg = VolumeGeometry::slice2d(n, n, 1.0);
        let g = ParallelBeam::standard_2d(90, (n * 3) / 2, 1.0);
        let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(Projector::new(
            Geometry::Parallel(g.clone()),
            vg.clone(),
            Model::SF,
        )));
        let coord = Arc::new(Coordinator::new(exec, BatchPolicy::default(), 1 << 30, 2));
        let vol = vec![0.01f32; vg.num_voxels()];
        let mut m = bench.run(&format!("native_fp {n}² via coordinator"), || {
            coord.call(Request::new(1, "native_fp", vec![vol.clone()]))
        });
        // compare to direct execution (no coordinator)
        let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
        let v3 = leap::Vol3::from_vec(n, n, 1, vol.clone());
        let direct = bench.run(&format!("native_fp {n}² direct"), || p.forward(&v3));
        let overhead = (m.mean_s - direct.mean_s).max(0.0) / direct.mean_s * 100.0;
        m.notes.push(("overhead_pct".into(), overhead));
        m.print();
        direct.print();
        println!("    → coordinator overhead {overhead:.1}%");
        all.push(m);
        all.push(direct);
    }
    append_results(&all);
}
