//! Reconstruction benchmarks: FBP/FDK and the iterative solvers on the
//! matched pairs — the "implementing analytical or iterative
//! reconstruction algorithms" claim, timed.
//!
//! Run: `cargo bench --bench recon`

use leap::bench_harness::{append_results, Bench};
use leap::geometry::{ConeBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;

fn main() {
    let bench = Bench::quick();
    let mut all = Vec::new();

    // 2-D parallel 128²/180
    let vg = VolumeGeometry::slice2d(128, 128, 1.0);
    let g = ParallelBeam::standard_2d(180, 192, 1.0);
    let ph = shepp::shepp_logan_2d(55.0, 0.02);
    let sino = ph.project(&Geometry::Parallel(g.clone()));
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);

    let m = bench.run("fbp parallel 128²/180 (hann)", || {
        recon::fbp_parallel(&vg, &g, &sino, recon::Window::Hann, 1)
    });
    m.print();
    all.push(m);

    for window in [recon::Window::RamLak, recon::Window::SheppLogan, recon::Window::Cosine] {
        let m = bench.run(&format!("fbp filter {}", window.name()), || {
            recon::fbp_parallel(&vg, &g, &sino, window, 1)
        });
        m.print();
        all.push(m);
    }

    let m = bench.run("sirt×10 sf 128²", || {
        recon::sirt(&p, &sino, &p.new_vol(), &recon::SirtOpts { iterations: 10, ..Default::default() })
    });
    m.print();
    all.push(m);

    let m = bench.run("os-sart×2(8 subsets) sf 128²", || {
        leap::recon::os_sart::os_sart(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::os_sart::OsSartOpts { iterations: 2, subsets: 8, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    let m = bench.run("cgls×10 sf 128²", || leap::recon::cgls::cgls(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("mlem×10 sf 128²", || leap::recon::mlem::mlem(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("fista-tv×10 sf 128²", || {
        leap::recon::fista_tv::fista_tv(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::fista_tv::FistaOpts { iterations: 10, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    // DC refinement (the Fig-3 hot loop)
    let mask = recon::ViewMask::contiguous(180, 0, 60);
    let mut masked = sino.clone();
    mask.apply(&mut masked);
    let pred = recon::fbp_parallel(&vg, &g, &masked, recon::Window::Hann, 1);
    let m = bench.run("dc-refine×20 (60°/180°)", || {
        recon::refine(&p, &masked, &mask, &pred, &recon::DcOpts { iterations: 20, ..Default::default() })
    });
    m.print();
    all.push(m);

    // 3-D FDK 48³/96
    let vg3 = VolumeGeometry::cube(48, 1.0);
    let g3 = ConeBeam::standard(96, 64, 80, 1.0, 1.0, 96.0, 192.0);
    let ph3 = shepp::shepp_logan_3d(20.0, 0.02);
    let sino3 = ph3.project(&Geometry::Cone(g3.clone()));
    let m = bench.run("fdk 48³/96 (hann)", || recon::fdk(&vg3, &g3, &sino3, recon::Window::Hann, 1));
    m.print();
    all.push(m);

    append_results(&all);
}
