//! Reconstruction benchmarks: FBP/FDK and the iterative solvers on the
//! matched pairs — the "implementing analytical or iterative
//! reconstruction algorithms" claim, timed.
//!
//! Run: `cargo bench --bench recon`

use leap::bench_harness::{append_results, Bench};
use leap::geometry::{ConeBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;
use leap::{Sino, Vol3};

/// The pre-`ProjectionPlan` SIRT loop: every `A`/`Aᵀ` application goes
/// through the direct path, re-deriving per-view geometry (trig, SF
/// footprints) each time. Kept as the baseline for the plan-reuse
/// acceptance bench; its output is bit-identical to `recon::sirt` because
/// the direct and planned paths share one execute code path.
fn sirt_unplanned(p: &Projector, y: &Sino, opts: &recon::SirtOpts) -> Vol3 {
    let row_sum = p.forward_ones();
    let mut col_ones = p.new_sino();
    col_ones.fill(1.0);
    let col_sum = p.back(&col_ones);
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut x = p.new_vol();
    let mut ax = p.new_sino();
    let mut grad = p.new_vol();
    for _ in 0..opts.iterations {
        p.forward_into(&x, &mut ax);
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        p.back_into(&ax, &mut grad);
        for i in 0..x.len() {
            let mut v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
            if opts.nonneg && v < 0.0 {
                v = 0.0;
            }
            x.data[i] = v;
        }
    }
    x
}

fn main() {
    let bench = Bench::quick();
    let mut all = Vec::new();

    // 2-D parallel 128²/180
    let vg = VolumeGeometry::slice2d(128, 128, 1.0);
    let g = ParallelBeam::standard_2d(180, 192, 1.0);
    let ph = shepp::shepp_logan_2d(55.0, 0.02);
    let sino = ph.project(&Geometry::Parallel(g.clone()));
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);

    let m = bench.run("fbp parallel 128²/180 (hann)", || {
        recon::fbp_parallel(&vg, &g, &sino, recon::Window::Hann, 1)
    });
    m.print();
    all.push(m);

    for window in [recon::Window::RamLak, recon::Window::SheppLogan, recon::Window::Cosine] {
        let m = bench.run(&format!("fbp filter {}", window.name()), || {
            recon::fbp_parallel(&vg, &g, &sino, window, 1)
        });
        m.print();
        all.push(m);
    }

    let m = bench.run("sirt×10 sf 128²", || {
        recon::sirt(&p, &sino, &p.new_vol(), &recon::SirtOpts { iterations: 10, ..Default::default() })
    });
    m.print();
    all.push(m);

    let m = bench.run("os-sart×2(8 subsets) sf 128²", || {
        leap::recon::os_sart::os_sart(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::os_sart::OsSartOpts { iterations: 2, subsets: 8, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    let m = bench.run("cgls×10 sf 128²", || leap::recon::cgls::cgls(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("mlem×10 sf 128²", || leap::recon::mlem::mlem(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("fista-tv×10 sf 128²", || {
        leap::recon::fista_tv::fista_tv(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::fista_tv::FistaOpts { iterations: 10, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    // DC refinement (the Fig-3 hot loop)
    let mask = recon::ViewMask::contiguous(180, 0, 60);
    let mut masked = sino.clone();
    mask.apply(&mut masked);
    let pred = recon::fbp_parallel(&vg, &g, &masked, recon::Window::Hann, 1);
    let m = bench.run("dc-refine×20 (60°/180°)", || {
        recon::refine(&p, &masked, &mask, &pred, &recon::DcOpts { iterations: 20, ..Default::default() })
    });
    m.print();
    all.push(m);

    // 3-D FDK 48³/96
    let vg3 = VolumeGeometry::cube(48, 1.0);
    let g3 = ConeBeam::standard(96, 64, 80, 1.0, 1.0, 96.0, 192.0);
    let ph3 = shepp::shepp_logan_3d(20.0, 0.02);
    let sino3 = ph3.project(&Geometry::Cone(g3.clone()));
    let m = bench.run("fdk 48³/96 (hann)", || recon::fdk(&vg3, &g3, &sino3, recon::Window::Hann, 1));
    m.print();
    all.push(m);

    // ── plan/execute acceptance: SIRT×50, cone beam, SF model ──
    // A few-row cone scan spends a large share of every operator
    // application on per-view footprint planning (corner projections,
    // trapezoid sort, column-bin integrals); ProjectionPlan computes them
    // once per solve. The two paths share one execute code path, so the
    // outputs are bit-identical — asserted below.
    let vgc = VolumeGeometry { nx: 64, ny: 64, nz: 6, vx: 1.0, vy: 1.0, vz: 1.0, cx: 0.0, cy: 0.0, cz: 0.0 };
    let gc = ConeBeam::standard(36, 8, 96, 1.0, 1.0, 128.0, 256.0);
    let pc = Projector::new(Geometry::Cone(gc), vgc.clone(), Model::SF);
    let phc = shepp::shepp_logan_3d(27.0, 0.02);
    let yc = pc.forward(&phc.rasterize(&vgc, 1));
    let sirt_opts = recon::SirtOpts { iterations: 50, ..Default::default() };

    let m_direct = bench.run("sirt×50 cone sf 64²×6 (direct, re-plans per application)", || {
        sirt_unplanned(&pc, &yc, &sirt_opts)
    });
    m_direct.print();
    let mut m_plan = bench.run("sirt×50 cone sf 64²×6 (plan built once per solve)", || {
        recon::sirt(&pc, &yc, &pc.new_vol(), &sirt_opts)
    });
    let speedup = m_direct.mean_s / m_plan.mean_s;
    m_plan.notes.push(("speedup_vs_direct".into(), speedup));
    m_plan.print();

    let direct_vol = sirt_unplanned(&pc, &yc, &sirt_opts);
    let plan_vol = recon::sirt(&pc, &yc, &pc.new_vol(), &sirt_opts).vol;
    assert_eq!(
        direct_vol.data, plan_vol.data,
        "plan-path SIRT must be bit-identical to the direct path"
    );
    println!("    → plan reuse: {speedup:.2}× on SIRT×50 (outputs bit-identical)");
    all.push(m_direct);
    all.push(m_plan);

    append_results(&all);
}
