//! Reconstruction benchmarks: FBP/FDK and the iterative solvers on the
//! matched pairs — the "implementing analytical or iterative
//! reconstruction algorithms" claim, timed.
//!
//! Run: `cargo bench --bench recon`
//!
//! Every measurement is appended as a JSON line to `BENCH_PR9.json` at
//! the repo root (the perf trajectory file; earlier PRs' history lives
//! in `BENCH_PR2.json`–`BENCH_PR8.json`) in addition to
//! `target/bench_results.jsonl`. Set `LEAP_BENCH_SMOKE=1` to run one
//! iteration of everything (the CI smoke step — including the
//! batched-coordinator, wire-protocol, tape-gradient,
//! scalar-vs-SIMD backend, storage-tier, out-of-core tiled-execution,
//! view-sharded operator and concurrent-session serving cases; the
//! backend sweep shrinks to one scalar row + one SIMD row, the storage
//! sweep to f32+f16, and the session sweep to 1/8 sessions, in smoke
//! mode).
//!
//! The storage-tier rows carry `rel_l2_*_vs_f32` accuracy deltas and
//! per-tier sinogram/table storage bytes; the tiled rows carry eviction
//! counts and residency budgets. Peak RSS is sampled from
//! `/proc/self/status` (`VmHWM`/`VmRSS`, kB) at measurement time — the
//! high-water mark is process-monotone, so size attribution comes from
//! the analytic `*_bytes` columns, not from subtracting rows (see
//! docs/MEMORY.md for the methodology).

use std::sync::Arc;
use std::time::Duration;

use leap::bench_harness::{append_results, append_results_to, smoke_mode, Bench};
use leap::coordinator::server::{BinaryClient, Client, Server};
use leap::coordinator::{
    BatchPolicy, Coordinator, Executor, NativeExecutor, Request, Router, SessionExecutor,
};
use leap::geometry::config::ScanConfig;
use leap::geometry::{
    ConeBeam, DetectorShape, FanBeam, Geometry, ModularBeam, ParallelBeam, VolumeGeometry,
};
use leap::ops::{LinearOp, Objective, PlanOp, ProjectionLoss};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;
use leap::tape::UnrollCfg;
use leap::util::pool::chunk_ranges;
use leap::{ScanBuilder, Sino, Vol3};

/// Where the perf trajectory lives: the repo root, independent of the
/// working directory cargo gives the bench binary.
const TRAJECTORY: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR9.json");

/// One field of `/proc/self/status` in kB (`VmHWM` = peak RSS,
/// `VmRSS` = current) — `None` off Linux, keeping the bench portable.
fn vm_kb(field: &str) -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with(field))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Attach the RSS sample to a measurement row.
fn push_rss(m: &mut leap::bench_harness::Measurement) {
    if let Some(hwm) = vm_kb("VmHWM") {
        m.notes.push(("vm_hwm_kb".into(), hwm));
    }
    if let Some(rss) = vm_kb("VmRSS") {
        m.notes.push(("vm_rss_kb".into(), rss));
    }
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - y as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

/// The pre-`ProjectionPlan` SIRT loop: every `A`/`Aᵀ` application goes
/// through the direct path, re-deriving per-view geometry (trig, SF
/// footprints) each time. Kept as the baseline for the plan-reuse
/// acceptance bench; its output is bit-identical to `recon::sirt` because
/// the direct and planned paths share one execute code path.
fn sirt_unplanned(p: &Projector, y: &Sino, opts: &recon::SirtOpts) -> Vol3 {
    let row_sum = p.forward_ones();
    let mut col_ones = p.new_sino();
    col_ones.fill(1.0);
    let col_sum = p.back(&col_ones);
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut x = p.new_vol();
    let mut ax = p.new_sino();
    let mut grad = p.new_vol();
    for _ in 0..opts.iterations {
        p.forward_into(&x, &mut ax);
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        p.back_into(&ax, &mut grad);
        for i in 0..x.len() {
            let mut v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
            if opts.nonneg && v < 0.0 {
                v = 0.0;
            }
            x.data[i] = v;
        }
    }
    x
}

/// The PR-1 backprojection *execution strategy*, preserved here as a
/// measurable baseline: one scoped OS-thread wave per application,
/// per-thread partial volumes (`threads × volume` scratch), serial
/// chunk-order fold — with per-view SF planning on the fly, like the
/// PR-1 direct path. Comparing this against today's direct path (which
/// also plans per view) isolates exactly what this PR changed: the
/// persistent pool plus slab-owned accumulation.
fn scatter_back_pr1_style(p: &Projector, sino: &Sino, vol: &mut Vol3) {
    let Geometry::Cone(g) = &p.geom else { panic!("cone-beam baseline only") };
    let nvox = p.vg.num_voxels();
    let nviews = g.angles.len();
    let ncols = g.ncols;
    let ranges = chunk_ranges(nviews, p.threads);
    let mut parts: Vec<Option<Vec<f32>>> = Vec::new();
    parts.resize_with(ranges.len(), || None);
    std::thread::scope(|scope| {
        for (slot, &(v0, v1)) in parts.iter_mut().zip(ranges.iter()) {
            let vg = &p.vg;
            scope.spawn(move || {
                let mut part = vec![0.0f32; nvox];
                for view in v0..v1 {
                    let vdata = sino.view(view);
                    leap::projector::sf::cone_view_coeffs_pub(
                        vg,
                        g,
                        view,
                        &mut |flat, row, col, coeff| {
                            part[flat] += (coeff as f32) * vdata[row * ncols + col];
                        },
                    );
                }
                *slot = Some(part);
            });
        }
    });
    vol.fill(0.0);
    for part in parts.into_iter().flatten() {
        for (d, s) in vol.data.iter_mut().zip(part.iter()) {
            *d += s;
        }
    }
}

/// SIRT with the PR-1-style scatter backprojection (see above).
fn sirt_pr1_scatter(p: &Projector, y: &Sino, opts: &recon::SirtOpts) -> Vol3 {
    let row_sum = p.forward_ones();
    let mut col_ones = p.new_sino();
    col_ones.fill(1.0);
    let mut col_sum = p.new_vol();
    scatter_back_pr1_style(p, &col_ones, &mut col_sum);
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let inv_col: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let mut x = p.new_vol();
    let mut ax = p.new_sino();
    let mut grad = p.new_vol();
    for _ in 0..opts.iterations {
        p.forward_into(&x, &mut ax);
        for i in 0..ax.len() {
            ax.data[i] = (y.data[i] - ax.data[i]) * inv_row[i];
        }
        scatter_back_pr1_style(p, &ax, &mut grad);
        for i in 0..x.len() {
            let mut v = x.data[i] + opts.lambda * inv_col[i] * grad.data[i];
            if opts.nonneg && v < 0.0 {
                v = 0.0;
            }
            x.data[i] = v;
        }
    }
    x
}

/// 1-thread vs N-thread outputs must be bit-identical for every model ×
/// geometry **per backend** — forward *and* slab-owned back (the PR-2
/// acceptance invariant, extended to both kernel tiers; asserted on
/// every bench run).
fn assert_thread_count_invariance() {
    let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
    let mut curved = cone.clone();
    curved.shape = DetectorShape::Curved;
    let geometries = vec![
        Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
        Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
        Geometry::Cone(cone.clone()),
        Geometry::Cone(curved),
        Geometry::Modular(ModularBeam::from_cone(&cone)),
    ];
    let mut rng = leap::util::rng::Rng::new(77);
    for geom in geometries {
        let vg = if matches!(geom, Geometry::Fan(_)) {
            VolumeGeometry::slice2d(12, 12, 1.0)
        } else {
            VolumeGeometry::cube(10, 1.0)
        };
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            for kind in [leap::backend::BackendKind::Scalar, leap::backend::BackendKind::Simd] {
                let p1 =
                    Projector::new(geom.clone(), vg.clone(), model).with_threads(1).with_backend(kind);
                let pn =
                    Projector::new(geom.clone(), vg.clone(), model).with_threads(4).with_backend(kind);
                let mut x = p1.new_vol();
                let mut y = p1.new_sino();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                assert_eq!(
                    p1.forward(&x).data,
                    pn.forward(&x).data,
                    "{}/{}/{} forward threads",
                    kind.name(),
                    model.name(),
                    p1.geom.kind()
                );
                assert_eq!(
                    p1.back(&y).data,
                    pn.back(&y).data,
                    "{}/{}/{} back threads",
                    kind.name(),
                    model.name(),
                    p1.geom.kind()
                );
            }
        }
    }
    println!(
        "thread-count invariance: 3 models × 5 geometries × 2 backends bit-identical (1 vs 4 threads)"
    );
}

fn main() {
    let smoke = smoke_mode();
    let bench = if smoke { Bench::smoke() } else { Bench::quick() };
    let mut all = Vec::new();

    assert_thread_count_invariance();

    // ── backend tiers: scalar vs simd kernels on the same coefficients ──
    // Forward+back per iteration through the planned path, per model ×
    // geometry × backend. Mvox/s counts voxels swept (A and Aᵀ each
    // sweep the volume once per application). The SIMD row carries
    // `speedup_simd_vs_scalar` against the scalar row measured just
    // before it; smoke mode keeps exactly one scalar + one SIMD row
    // (SF/parallel) so the CI step stays fast.
    {
        use leap::backend::BackendKind;
        let backend_cases: Vec<(&str, Geometry, VolumeGeometry)> = vec![
            (
                "parallel 48³/60",
                Geometry::Parallel(ParallelBeam::standard_3d(60, 48, 64, 1.0, 1.0)),
                VolumeGeometry::cube(48, 1.0),
            ),
            (
                "fan 128²/180",
                Geometry::Fan(FanBeam::standard(180, 192, 1.0, 256.0, 512.0)),
                VolumeGeometry::slice2d(128, 128, 1.0),
            ),
            (
                "cone 48³/48",
                Geometry::Cone(ConeBeam::standard(48, 48, 64, 1.0, 1.0, 96.0, 192.0)),
                VolumeGeometry::cube(48, 1.0),
            ),
        ];
        let backend_models: &[Model] =
            if smoke { &[Model::SF] } else { &[Model::Siddon, Model::Joseph, Model::SF] };
        let backend_cases = if smoke { &backend_cases[..1] } else { &backend_cases[..] };
        for (gname, geom, vgb) in backend_cases {
            for &model in backend_models {
                let nvox_b = vgb.num_voxels();
                let mut scalar_mean = f64::NAN;
                for kind in [BackendKind::Scalar, BackendKind::Simd] {
                    let p = Projector::new(geom.clone(), vgb.clone(), model).with_backend(kind);
                    let plan = p.plan();
                    let mut x = p.new_vol();
                    leap::util::rng::Rng::new(88).fill_uniform(&mut x.data, 0.0, 1.0);
                    let mut y = p.new_sino();
                    let mut back = p.new_vol();
                    let mut m = bench.run(
                        &format!("proj fp+bp {} {gname} [{}]", model.name(), kind.name()),
                        || {
                            p.forward_with_plan(&plan, &x, &mut y);
                            p.back_with_plan(&plan, &y, &mut back);
                        },
                    );
                    let mvox_b = nvox_b as f64 * 2.0 / m.mean_s / 1e6;
                    m.notes.push(("mvox_per_s".into(), mvox_b));
                    m.notes.push(("threads".into(), p.threads as f64));
                    if kind == BackendKind::Scalar {
                        scalar_mean = m.mean_s;
                    } else {
                        let speedup = scalar_mean / m.mean_s;
                        m.notes.push(("speedup_simd_vs_scalar".into(), speedup));
                        println!(
                            "    → simd vs scalar: {speedup:.2}× on {} {gname} ({mvox_b:.1} Mvox/s)",
                            model.name()
                        );
                    }
                    m.print();
                    all.push(m);
                }
            }
        }
    }

    // ── storage tiers: f32 vs f16 vs bf16 data-at-rest ──
    // The same planned fp+bp per tier. The cone case is where the tier
    // has teeth (the cached SF coefficient arena packs to 16-bit weight
    // bits, halving the dominant plan allocation) and where forward
    // accuracy is "quantized tables"; backprojection additionally
    // quantizes its sinogram input on every tier ≠ f32. Each row carries
    // Mvox/s, the rel-l2 delta against the f32 tier measured on the same
    // inputs, per-tier sinogram storage bytes, and the VmHWM/VmRSS
    // sample (methodology: module docs).
    {
        use leap::precision::TieredSino;
        use leap::StorageTier;
        let tier_cases: Vec<(&str, Geometry, VolumeGeometry)> = vec![
            (
                "cone 48³/48",
                Geometry::Cone(ConeBeam::standard(48, 48, 64, 1.0, 1.0, 96.0, 192.0)),
                VolumeGeometry::cube(48, 1.0),
            ),
            (
                "parallel 48³/60",
                Geometry::Parallel(ParallelBeam::standard_3d(60, 48, 64, 1.0, 1.0)),
                VolumeGeometry::cube(48, 1.0),
            ),
        ];
        let tiers: &[StorageTier] = if smoke {
            &[StorageTier::F32, StorageTier::F16]
        } else {
            &[StorageTier::F32, StorageTier::F16, StorageTier::Bf16]
        };
        let tier_cases = if smoke { &tier_cases[..1] } else { &tier_cases[..] };
        for (gname, geom, vgt) in tier_cases {
            let nvox_t = vgt.num_voxels();
            let mut x = Vol3::zeros(vgt.nx, vgt.ny, vgt.nz);
            leap::util::rng::Rng::new(89).fill_uniform(&mut x.data, 0.0, 1.0);
            // per-tier accuracy is measured against the f32 tier's
            // outputs on identical inputs (the first loop iteration)
            let mut fwd_ref: Vec<f32> = Vec::new();
            let mut back_ref: Vec<f32> = Vec::new();
            let mut f32_mean = f64::NAN;
            for &tier in tiers {
                let p = Projector::new(geom.clone(), vgt.clone(), Model::SF)
                    .with_storage_tier(tier);
                let plan = p.plan();
                let mut y = p.new_sino();
                let mut back = p.new_vol();
                let mut m = bench.run(
                    &format!("proj fp+bp sf {gname} [storage {}]", tier.name()),
                    || {
                        p.forward_with_plan(&plan, &x, &mut y);
                        p.back_with_plan(&plan, &y, &mut back);
                    },
                );
                let mvox_t = nvox_t as f64 * 2.0 / m.mean_s / 1e6;
                m.notes.push(("mvox_per_s".into(), mvox_t));
                m.notes.push(("threads".into(), p.threads as f64));
                m.notes.push((
                    "sino_storage_bytes".into(),
                    TieredSino::from_sino(tier, &y).storage_bytes() as f64,
                ));
                push_rss(&mut m);
                if tier == StorageTier::F32 {
                    f32_mean = m.mean_s;
                    fwd_ref = y.data.clone();
                    back_ref = back.data.clone();
                } else {
                    let d_fwd = rel_l2(&y.data, &fwd_ref);
                    let d_back = rel_l2(&back.data, &back_ref);
                    assert!(
                        d_fwd <= 1e-3 && d_back <= 1e-3,
                        "{} {gname}: tier accuracy out of class (fwd {d_fwd}, back {d_back})",
                        tier.name()
                    );
                    m.notes.push(("rel_l2_fwd_vs_f32".into(), d_fwd));
                    m.notes.push(("rel_l2_back_vs_f32".into(), d_back));
                    m.notes.push(("speedup_vs_f32_tier".into(), f32_mean / m.mean_s));
                    println!(
                        "    → {} vs f32 on {gname}: rel-l2 fwd {d_fwd:.2e} back {d_back:.2e} \
                         ({mvox_t:.1} Mvox/s)",
                        tier.name()
                    );
                }
                m.print();
                all.push(m);
            }
        }
    }

    // ── out-of-core tiled execution: peak RSS vs volume size ──
    // The same scalar-SF cone forward, resident vs tiled under a
    // residency budget of 1/8 of the volume (which forces repeated
    // evictions — asserted). Tiled output is bit-identical to resident
    // output (also asserted, every run). The row pairs volume bytes with
    // the budget that bounded tile residency and the VmHWM sample, which
    // is the peak-RSS-vs-volume-size trajectory; `evictions` says how
    // hard the budget squeezed.
    {
        let tiled_cases: Vec<(&str, usize, usize)> = if smoke {
            vec![("cone 32³/24 tiled", 32, 24)]
        } else {
            vec![("cone 48³/48 tiled", 48, 48), ("cone 96³/48 tiled", 96, 48)]
        };
        for (tname, n, nviews) in tiled_cases {
            let vgo = VolumeGeometry::cube(n, 1.0);
            let go = ConeBeam::standard(nviews, n, (n * 4).div_ceil(3), 1.0, 1.0, 2.0 * n as f64, 4.0 * n as f64);
            let po = Projector::new(Geometry::Cone(go), vgo.clone(), Model::SF)
                .with_backend(leap::backend::BackendKind::Scalar);
            let plan = po.plan();
            let mut x = po.new_vol();
            leap::util::rng::Rng::new(90).fill_uniform(&mut x.data, 0.0, 1.0);
            let volume_bytes = vgo.num_voxels() * 4;
            let budget = (volume_bytes / 8).max(plan.window_planes() * vgo.nx * 4);
            let mut resident = po.new_sino();
            let mut m_res = bench.run(&format!("{tname} resident forward"), || {
                plan.forward_into(&x, &mut resident)
            });
            m_res.notes.push(("volume_bytes".into(), volume_bytes as f64));
            push_rss(&mut m_res);
            m_res.print();
            let mut tiled = po.new_sino();
            let evictions =
                leap::vol::tiled_forward_into(&plan, &x, &mut tiled, budget).expect("tiled forward");
            assert_eq!(
                tiled.data, resident.data,
                "{tname}: tiled forward must be bit-identical to resident"
            );
            assert!(evictions >= 2, "{tname}: budget {budget} should evict (got {evictions})");
            let mut m_tiled = bench.run(&format!("{tname} forward (budget {budget} B)"), || {
                leap::vol::tiled_forward_into(&plan, &x, &mut tiled, budget).expect("tiled forward")
            });
            let overhead = m_tiled.mean_s / m_res.mean_s;
            m_tiled.notes.push(("volume_bytes".into(), volume_bytes as f64));
            m_tiled.notes.push(("budget_bytes".into(), budget as f64));
            m_tiled.notes.push(("evictions".into(), evictions as f64));
            m_tiled.notes.push(("tiled_over_resident".into(), overhead));
            push_rss(&mut m_tiled);
            m_tiled.print();
            println!(
                "    → tiled vs resident on {tname}: {overhead:.2}× at a {budget} B budget \
                 ({evictions} evictions, bit-identical)"
            );
            all.push(m_res);
            all.push(m_tiled);
        }
    }

    // 2-D parallel 128²/180
    let vg = VolumeGeometry::slice2d(128, 128, 1.0);
    let g = ParallelBeam::standard_2d(180, 192, 1.0);
    let ph = shepp::shepp_logan_2d(55.0, 0.02);
    let sino = ph.project(&Geometry::Parallel(g.clone()));
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);

    let m = bench.run("fbp parallel 128²/180 (hann)", || {
        recon::fbp_parallel(&vg, &g, &sino, recon::Window::Hann, 1)
    });
    m.print();
    all.push(m);

    for window in [recon::Window::RamLak, recon::Window::SheppLogan, recon::Window::Cosine] {
        let m = bench.run(&format!("fbp filter {}", window.name()), || {
            recon::fbp_parallel(&vg, &g, &sino, window, 1)
        });
        m.print();
        all.push(m);
    }

    let m = bench.run("sirt×10 sf 128²", || {
        recon::sirt(&p, &sino, &p.new_vol(), &recon::SirtOpts { iterations: 10, ..Default::default() })
    });
    m.print();
    all.push(m);

    let m = bench.run("os-sart×2(8 subsets) sf 128²", || {
        leap::recon::os_sart::os_sart(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::os_sart::OsSartOpts { iterations: 2, subsets: 8, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    let m = bench.run("cgls×10 sf 128²", || leap::recon::cgls::cgls(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("mlem×10 sf 128²", || leap::recon::mlem::mlem(&p, &sino, 10));
    m.print();
    all.push(m);

    let m = bench.run("fista-tv×10 sf 128²", || {
        leap::recon::fista_tv::fista_tv(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::fista_tv::FistaOpts { iterations: 10, ..Default::default() },
        )
    });
    m.print();
    all.push(m);

    // DC refinement (the Fig-3 hot loop)
    let mask = recon::ViewMask::contiguous(180, 0, 60);
    let mut masked = sino.clone();
    mask.apply(&mut masked);
    let pred = recon::fbp_parallel(&vg, &g, &masked, recon::Window::Hann, 1);
    let m = bench.run("dc-refine×20 (60°/180°)", || {
        recon::refine(&p, &masked, &mask, &pred, &recon::DcOpts { iterations: 20, ..Default::default() })
    });
    m.print();
    all.push(m);

    // 3-D FDK 48³/96
    let vg3 = VolumeGeometry::cube(48, 1.0);
    let g3 = ConeBeam::standard(96, 64, 80, 1.0, 1.0, 96.0, 192.0);
    let ph3 = shepp::shepp_logan_3d(20.0, 0.02);
    let sino3 = ph3.project(&Geometry::Cone(g3.clone()));
    let m = bench.run("fdk 48³/96 (hann)", || recon::fdk(&vg3, &g3, &sino3, recon::Window::Hann, 1));
    m.print();
    all.push(m);

    // ── plan/execute + pool/slab acceptance: SIRT×50, cone beam, SF ──
    // Three variants of the same solve isolate the two optimizations:
    //   pr1-scatter : PR-1 execution — scoped thread spawns per op,
    //                 threads×volume partial copies, serial reduce
    //   direct      : today's executors, per-view planning on the fly
    //                 (vs pr1-scatter: isolates pool + slab-owned back)
    //   plan        : today's executors through a prebuilt plan
    //                 (vs direct: isolates plan reuse)
    // All three produce identical volumes (asserted below).
    let vgc = VolumeGeometry { nx: 64, ny: 64, nz: 6, vx: 1.0, vy: 1.0, vz: 1.0, cx: 0.0, cy: 0.0, cz: 0.0 };
    let gc = ConeBeam::standard(36, 8, 96, 1.0, 1.0, 128.0, 256.0);
    let pc = Projector::new(Geometry::Cone(gc), vgc.clone(), Model::SF);
    let phc = shepp::shepp_logan_3d(27.0, 0.02);
    let yc = pc.forward(&phc.rasterize(&vgc, 1));
    let iters = if smoke { 2 } else { 50 };
    let sirt_opts = recon::SirtOpts { iterations: iters, ..Default::default() };
    let nvox = vgc.nx * vgc.ny * vgc.nz;
    // voxels touched per solve: A and Aᵀ each sweep the volume once per
    // iteration (plus the two normalization applications)
    let sweeps = (2 * iters + 2) as f64;
    let mvox = |mean_s: f64| nvox as f64 * sweeps / mean_s / 1e6;

    let name = format!("sirt×{iters} cone sf 64²×6");
    let mut m_pr1 = bench.run(&format!("{name} (pr1-style: spawn + scatter partials)"), || {
        sirt_pr1_scatter(&pc, &yc, &sirt_opts)
    });
    m_pr1.notes.push(("mvox_per_s".into(), mvox(m_pr1.mean_s)));
    m_pr1.notes.push(("back_scratch_bytes".into(), (pc.threads * nvox * 4) as f64));
    m_pr1.print();

    let mut m_direct = bench.run(&format!("{name} (direct, re-plans per application)"), || {
        sirt_unplanned(&pc, &yc, &sirt_opts)
    });
    m_direct.notes.push(("mvox_per_s".into(), mvox(m_direct.mean_s)));
    m_direct.notes.push(("back_scratch_bytes".into(), 0.0));
    m_direct.print();

    let mut m_plan = bench.run(&format!("{name} (plan built once per solve)"), || {
        recon::sirt(&pc, &yc, &pc.new_vol(), &sirt_opts)
    });
    let speedup_pool_slab = m_pr1.mean_s / m_direct.mean_s;
    let speedup_plan = m_direct.mean_s / m_plan.mean_s;
    let speedup_total = m_pr1.mean_s / m_plan.mean_s;
    m_plan.notes.push(("mvox_per_s".into(), mvox(m_plan.mean_s)));
    m_plan.notes.push(("back_scratch_bytes".into(), 0.0));
    m_plan.notes.push(("speedup_pool_slab_vs_pr1".into(), speedup_pool_slab));
    m_plan.notes.push(("speedup_vs_direct".into(), speedup_plan));
    m_plan.notes.push(("speedup_total_vs_pr1".into(), speedup_total));
    m_plan.notes.push(("threads".into(), pc.threads as f64));
    m_plan.print();

    let pr1_vol = sirt_pr1_scatter(&pc, &yc, &sirt_opts);
    let direct_vol = sirt_unplanned(&pc, &yc, &sirt_opts);
    let plan_vol = recon::sirt(&pc, &yc, &pc.new_vol(), &sirt_opts).vol;
    assert_eq!(
        direct_vol.data, plan_vol.data,
        "plan-path SIRT must be bit-identical to the direct path"
    );
    // the pr1-style scatter folds partials in the same (view-major, then
    // chunk-order) accumulation order per voxel only at 1 thread; at N
    // threads its per-voxel order differs, so compare within float noise
    let max_dev = pr1_vol
        .data
        .iter()
        .zip(plan_vol.data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 1e-3, "pr1-style baseline deviates: {max_dev}");
    println!(
        "    → pool+slab vs pr1 scatter: {speedup_pool_slab:.2}× | plan reuse: {speedup_plan:.2}× | \
         total: {speedup_total:.2}× on SIRT×{iters} at {} threads",
        pc.threads
    );
    println!(
        "    → back scratch: {} B (pr1: threads×volume partials) → 0 B (slab-owned)",
        pc.threads * nvox * 4
    );
    all.push(m_pr1);
    all.push(m_direct);
    all.push(m_plan);

    // ── batched serving: one apply_batch_into per closed batch ──
    // The same B in-flight native_fp requests through two coordinators:
    //   sequential : max_batch = 1 — every request is its own backend
    //                call (its own pool dispatch)
    //   batched    : max_batch = B — the backlog closes into
    //                multi-request batches, each executed as ONE stacked
    //                batched operator application (one plan fetch, one
    //                pool dispatch; workers split across the items)
    // Outputs are bit-identical either way (asserted), so the row
    // isolates pure serving throughput.
    let vgs = VolumeGeometry::slice2d(96, 96, 1.0);
    let gs = ParallelBeam::standard_2d(120, 128, 1.0);
    let ps = Projector::new(Geometry::Parallel(gs.clone()), vgs.clone(), Model::SF);
    let reference = {
        let plan = ps.plan();
        let mut vol = ps.new_vol();
        vol.fill(0.01);
        plan.forward(&vol).data
    };
    let nreq = 8usize;
    let vol_in = vec![0.01f32; vgs.num_voxels()];
    let serve = |max_batch: usize| {
        let coord = Coordinator::new(
            Arc::new(NativeExecutor::new(ps.clone())),
            BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
            1 << 30,
            1,
        );
        // warm the lazy plan fetch out of the timed region
        let warm = coord.call(Request::new(0, "native_fp", vec![vol_in.clone()]));
        assert_eq!(warm.outputs[0], reference, "served output must match the plan path");
        coord
    };
    let coord_seq = serve(1);
    let coord_bat = serve(nreq);
    let run_requests = |coord: &Coordinator| {
        let rxs: Vec<_> = (0..nreq as u64)
            .map(|i| coord.submit(Request::new(i, "native_fp", vec![vol_in.clone()])))
            .collect();
        for rx in rxs {
            let r = rx.recv().expect("response");
            assert!(r.ok(), "{:?}", r.error);
            assert_eq!(r.outputs[0], reference, "batched must stay bit-identical");
        }
    };
    let mut m_seq = bench.run(&format!("coordinator {nreq}×native_fp sequential (max_batch=1)"), || {
        run_requests(&coord_seq)
    });
    m_seq.notes.push(("req_per_s".into(), nreq as f64 / m_seq.mean_s));
    m_seq.print();
    let mut m_bat = bench.run(&format!("coordinator {nreq}×native_fp batched (max_batch={nreq})"), || {
        run_requests(&coord_bat)
    });
    let speedup_batched = m_seq.mean_s / m_bat.mean_s;
    m_bat.notes.push(("req_per_s".into(), nreq as f64 / m_bat.mean_s));
    m_bat.notes.push(("speedup_batched_vs_sequential".into(), speedup_batched));
    let snap = coord_bat.telemetry().snapshot();
    m_bat.notes.push(("mean_batch".into(), snap["native_fp"].mean_batch()));
    m_bat.print();
    println!(
        "    → batched coordinator vs sequential: {speedup_batched:.2}× on {nreq} in-flight \
         native_fp (mean batch {:.2})",
        snap["native_fp"].mean_batch()
    );
    all.push(m_seq);
    all.push(m_bat);

    // ── wire protocols: v2 binary sessions vs v1 JSON per-request ──
    // The same 8×native_fp workload through the real TCP stack on both
    // protocols. v1 re-sends every f32 as decimal JSON text against a
    // statically-configured backend; v2 registers the scan once over the
    // session handshake, then streams 24-byte headers + raw LE f32
    // tensors. Outputs are asserted bit-identical to the in-process plan
    // path on every request, so the row isolates pure wire overhead.
    let wire_backends: Vec<Arc<dyn Executor>> = vec![
        Arc::new(NativeExecutor::new(ps.clone())),
        Arc::new(SessionExecutor::new()),
    ];
    let wire_coord = Arc::new(Coordinator::new(
        Arc::new(Router::new(wire_backends)),
        BatchPolicy { max_batch: nreq, max_wait: Duration::from_millis(2) },
        1 << 30,
        1,
    ));
    let server = Server::start("127.0.0.1:0", wire_coord.clone()).expect("bench server");
    let cfg = ScanConfig { geometry: Geometry::Parallel(gs.clone()), volume: vgs.clone() };

    let mut v1_client = Client::connect(&server.addr).expect("v1 client");
    let run_v1 = |client: &mut Client| {
        for _ in 0..nreq {
            let sino = client.call_tensor("native_fp", &vol_in).expect("v1 reply");
            assert_eq!(sino, reference, "v1 JSON must stay bit-identical");
        }
    };
    run_v1(&mut v1_client); // warm (plan fetch + connection)
    let mut m_v1 = bench.run(&format!("wire {nreq}×native_fp v1 json per-request"), || {
        run_v1(&mut v1_client)
    });
    m_v1.notes.push(("req_per_s".into(), nreq as f64 / m_v1.mean_s));
    m_v1.print();

    let mut v2_client = BinaryClient::connect(&server.addr).expect("v2 client");
    let session = v2_client
        .open_session(&cfg, Model::SF, None)
        .expect("v2 session handshake");
    let run_v2 = |client: &mut BinaryClient| {
        for _ in 0..nreq {
            let sino = client.forward(session, &vol_in).expect("v2 reply");
            assert_eq!(sino, reference, "v2 binary must stay bit-identical");
        }
    };
    run_v2(&mut v2_client); // warm
    let mut m_v2 = bench.run(&format!("wire {nreq}×native_fp v2 binary session"), || {
        run_v2(&mut v2_client)
    });
    let speedup_v2 = m_v1.mean_s / m_v2.mean_s;
    m_v2.notes.push(("req_per_s".into(), nreq as f64 / m_v2.mean_s));
    m_v2.notes.push(("speedup_v2_binary_vs_v1_json".into(), speedup_v2));
    // wire cost per request (request direction): v2 = fixed header +
    // tiny meta + 4 B/sample; v1 = the JSON text it actually sends
    let v2_request_bytes = leap::coordinator::wire::encode_frame(
        &leap::coordinator::request::request_to_frame(
            1,
            &leap::coordinator::Op::SessionFp(session),
            vol_in.clone(),
        ),
    )
    .expect("frame within wire caps")
    .len();
    let v1_request_bytes = {
        use leap::util::json::Json;
        Json::obj(vec![
            ("id", Json::Num(1.0)),
            ("op", Json::Str("native_fp".into())),
            (
                "inputs",
                Json::Arr(vec![Json::Arr(
                    vol_in.iter().map(|&x| Json::Num(x as f64)).collect(),
                )]),
            ),
        ])
        .to_string()
        .len()
    };
    m_v2.notes.push(("v2_request_bytes".into(), v2_request_bytes as f64));
    m_v2.notes.push(("v1_request_bytes".into(), v1_request_bytes as f64));
    m_v2.print();
    v2_client.close_session(session).expect("close session");
    println!(
        "    → v2 binary sessions vs v1 json: {speedup_v2:.2}× on {nreq}×native_fp \
         ({v2_request_bytes} B vs {v1_request_bytes} B per request)"
    );
    all.push(m_v1);
    all.push(m_v2);
    drop(server);

    // ── tape gradients: fwd-only vs fwd+bwd, in-process vs served ──
    // (a) the price of the exact gradient: ProjectionLoss::value runs
    //     one forward projection, value_and_grad adds the matched
    //     backprojection — the ratio should sit near 2×, which is the
    //     paper's "gradients at the cost of one extra projection" claim
    //     made measurable.
    let loss_op = PlanOp::new(&ps);
    let loss = ProjectionLoss::new(&loss_op, &reference, Objective::LeastSquares);
    let nvox_s = vgs.num_voxels();
    let mut grad_buf = vec![0.0f32; nvox_s];
    let mut m_fwd = bench.run("tape loss fwd-only (value)", || {
        leap::bench_harness::black_box(loss.value(&vol_in))
    });
    m_fwd.print();
    let mut m_grad = bench.run("tape loss fwd+bwd (value_and_grad)", || {
        leap::bench_harness::black_box(loss.value_and_grad(&vol_in, &mut grad_buf))
    });
    let bwd_ratio = m_grad.mean_s / m_fwd.mean_s;
    m_grad.notes.push(("fwd_plus_bwd_over_fwd".into(), bwd_ratio));
    m_grad.print();
    println!("    → exact gradient costs {bwd_ratio:.2}× the forward-only loss");
    all.push(m_fwd);
    all.push(m_grad);

    // (b) a K=2 unrolled pipeline's loss+gradients: in-process tape vs
    //     Op::SessionPipelineGrad over the real TCP stack (registered
    //     once, then one packed request per evaluation). Bit-identity is
    //     asserted on every served reply, so the row isolates pure
    //     serving overhead on a training-loop-shaped workload.
    let cfg = ScanConfig { geometry: Geometry::Parallel(gs.clone()), volume: vgs.clone() };
    let grad_scan = ScanBuilder::from_config(&cfg).model(Model::SF).build().expect("scan");
    let grad_op: Arc<dyn LinearOp> = Arc::new(PlanOp::from_plan(grad_scan.plan().clone()));
    let pipe = leap::tape::unrolled_gd(
        grad_op,
        &UnrollCfg { iterations: 2, step_init: 0.005, nonneg: true },
    )
    .expect("unrolled pipeline");
    let params: Vec<Vec<f32>> = pipe.params().iter().map(|p| p.value.clone()).collect();
    let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let grad_inputs: Vec<&[f32]> = vec![&reference, &vol_in]; // [sino, truth]
    let (l_local, g_local) = pipe.loss_and_grads_with(&pr, &grad_inputs).expect("local grads");
    let mut m_tape_local = bench.run("tape pipeline_grad K=2 in-process", || {
        let (l, g) = pipe.loss_and_grads_with(&pr, &grad_inputs).expect("local grads");
        assert_eq!(l.to_bits(), l_local.to_bits());
        leap::bench_harness::black_box(g)
    });
    m_tape_local.print();

    let grad_backends: Vec<Arc<dyn Executor>> = vec![
        Arc::new(NativeExecutor::new(ps.clone())),
        Arc::new(SessionExecutor::new()),
    ];
    let grad_coord = Arc::new(Coordinator::new(
        Arc::new(Router::new(grad_backends)),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        1 << 30,
        1,
    ));
    let grad_server = Server::start("127.0.0.1:0", grad_coord).expect("bench server");
    let mut grad_client = BinaryClient::connect(&grad_server.addr).expect("v2 client");
    let session = grad_client
        .open_session(&cfg, Model::SF, None)
        .expect("session handshake");
    let pid = grad_client.register_pipeline(session, &pipe).expect("register pipeline");
    let run_served = |client: &mut BinaryClient| {
        let (l, g) = client
            .pipeline_grad(session, pid, &pipe, &pr, &grad_inputs)
            .expect("served grads");
        assert_eq!(l.to_bits(), l_local.to_bits(), "served loss must be bit-identical");
        assert_eq!(g, g_local, "served gradients must be bit-identical");
    };
    run_served(&mut grad_client); // warm (plan + registration already done)
    let mut m_tape_served = bench.run("tape pipeline_grad K=2 served (v2 session)", || {
        run_served(&mut grad_client)
    });
    let served_overhead = m_tape_served.mean_s / m_tape_local.mean_s;
    m_tape_served
        .notes
        .push(("served_over_in_process".into(), served_overhead));
    m_tape_served.print();
    println!(
        "    → served pipeline gradients cost {served_overhead:.2}× the in-process tape \
         (bit-identical replies asserted)"
    );
    grad_client.close_session(session).expect("close session");
    drop(grad_server);
    all.push(m_tape_local);
    all.push(m_tape_served);

    // ── neural tape nodes: direct conv kernel throughput ──
    // The tape's Conv2d/Conv3d nodes dispatch to these direct
    // (im2col-free) kernels (rust/src/nn/); the rows record forward and
    // full-backward (input + weight + bias VJPs) throughput in output
    // Mcell/s so kernel regressions land in the perf trajectory. The
    // corpus row proves the seeded phantom corpus regenerates
    // bit-identically — training data is a pure function of
    // (family, count, seed), which is what makes every training run in
    // the suite reproducible.
    {
        use leap::nn;
        let (cw, ch, cin, cout, k) = (96usize, 96usize, 8usize, 8usize, 3usize);
        let mut cx = vec![0.0f32; cw * ch * cin];
        leap::util::rng::Rng::new(61).fill_uniform(&mut cx, 0.0, 1.0);
        let cwt = nn::conv_init(7, k * k, cin, cout);
        let cb = vec![0.05f32; cout];
        let mut cy = vec![0.0f32; cw * ch * cout];
        let cells2 = (cw * ch * cout) as f64;
        let mut m = bench.run(&format!("nn conv2d fwd {cw}×{ch} c{cin}→c{cout} k{k}"), || {
            nn::conv2d_forward(&cx, &cwt, &cb, cw, ch, cin, cout, k, &mut cy);
            leap::bench_harness::black_box(cy[0])
        });
        m.notes.push(("out_mcells_per_s".into(), cells2 / m.mean_s / 1e6));
        m.print();
        all.push(m);

        nn::conv2d_forward(&cx, &cwt, &cb, cw, ch, cin, cout, k, &mut cy);
        let dy2 = cy.clone();
        let mut dx2 = vec![0.0f32; cw * ch * cin];
        let mut dw2 = vec![0.0f32; k * k * cin * cout];
        let mut db2 = vec![0.0f32; cout];
        let mut m = bench.run(&format!("nn conv2d bwd {cw}×{ch} c{cin}→c{cout} k{k}"), || {
            dx2.iter_mut().for_each(|v| *v = 0.0);
            dw2.iter_mut().for_each(|v| *v = 0.0);
            db2.iter_mut().for_each(|v| *v = 0.0);
            nn::conv2d_input_grad(&dy2, &cwt, cw, ch, cin, cout, k, &mut dx2);
            nn::conv2d_weight_grad(&cx, &dy2, cw, ch, cin, cout, k, &mut dw2);
            nn::conv2d_bias_grad(&dy2, cw, ch, cout, &mut db2);
            leap::bench_harness::black_box(dx2[0])
        });
        m.notes.push(("out_mcells_per_s".into(), cells2 / m.mean_s / 1e6));
        m.print();
        all.push(m);

        let (vw, vh, vz, ci3, co3) = (32usize, 32usize, 16usize, 4usize, 4usize);
        let mut x3 = vec![0.0f32; vw * vh * vz * ci3];
        leap::util::rng::Rng::new(62).fill_uniform(&mut x3, 0.0, 1.0);
        let w3 = nn::conv_init(8, k * k * k, ci3, co3);
        let b3 = vec![0.05f32; co3];
        let mut y3 = vec![0.0f32; vw * vh * vz * co3];
        let cells3 = (vw * vh * vz * co3) as f64;
        let mut m = bench.run(&format!("nn conv3d fwd {vw}×{vh}×{vz} c{ci3}→c{co3} k{k}"), || {
            nn::conv3d_forward(&x3, &w3, &b3, vw, vh, vz, ci3, co3, k, &mut y3);
            leap::bench_harness::black_box(y3[0])
        });
        m.notes.push(("out_mcells_per_s".into(), cells3 / m.mean_s / 1e6));
        m.print();
        all.push(m);

        nn::conv3d_forward(&x3, &w3, &b3, vw, vh, vz, ci3, co3, k, &mut y3);
        let dy3 = y3.clone();
        let mut dx3 = vec![0.0f32; vw * vh * vz * ci3];
        let mut dw3 = vec![0.0f32; k * k * k * ci3 * co3];
        let mut db3 = vec![0.0f32; co3];
        let mut m = bench.run(&format!("nn conv3d bwd {vw}×{vh}×{vz} c{ci3}→c{co3} k{k}"), || {
            dx3.iter_mut().for_each(|v| *v = 0.0);
            dw3.iter_mut().for_each(|v| *v = 0.0);
            db3.iter_mut().for_each(|v| *v = 0.0);
            nn::conv3d_input_grad(&dy3, &w3, vw, vh, vz, ci3, co3, k, &mut dx3);
            nn::conv3d_weight_grad(&x3, &dy3, vw, vh, vz, ci3, co3, k, &mut dw3);
            nn::conv3d_bias_grad(&dy3, vw, vh, vz, co3, &mut db3);
            leap::bench_harness::black_box(dx3[0])
        });
        m.notes.push(("out_mcells_per_s".into(), cells3 / m.mean_s / 1e6));
        m.print();
        all.push(m);

        use leap::phantom::corpus::{Corpus, CorpusCfg, Family};
        let cvg = VolumeGeometry::slice2d(96, 96, 1.0);
        let ccfg = CorpusCfg { family: Family::SheppJitter, count: 4, ..CorpusCfg::default() };
        let corpus = Corpus::new(ccfg.clone(), &cvg, 2024).expect("bench corpus");
        let truth_bits: Vec<Vec<u32>> = (0..4u64)
            .map(|id| corpus.truth(id).data.iter().map(|v| v.to_bits()).collect())
            .collect();
        let mut m = bench.run("phantom corpus 4×96² shepp-jitter (deterministic)", || {
            let again = Corpus::new(ccfg.clone(), &cvg, 2024).expect("bench corpus");
            for (id, want) in truth_bits.iter().enumerate() {
                let got: Vec<u32> =
                    again.truth(id as u64).data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(&got, want, "corpus item {id} must regenerate bit-identically");
            }
            leap::bench_harness::black_box(truth_bits.len())
        });
        m.notes.push(("items".into(), 4.0));
        m.print();
        all.push(m);
    }

    // ── view-sharded operator execution ──
    // One LinearOp application split into S sequential pool regions —
    // by view-subsets (forward) / volume-slab-subsets (back). Identical
    // bits at every shard count (asserted per application below and
    // property-tested in ops::tests); the finer regions interleave
    // fairly in the pool FIFO, which is what buys the serving plane its
    // tail-latency win when many sessions share the workers. This row
    // measures what the finer granularity costs on a solo application.
    {
        use leap::ops::ViewSharded;
        let plan = Arc::new(ps.plan());
        let mut xin = vec![0.0f32; vgs.num_voxels()];
        leap::util::rng::Rng::new(91).fill_uniform(&mut xin, 0.0, 1.0);
        let base = ViewSharded::new(plan.clone(), 1);
        let ref_fwd = base.apply(&xin);
        let ref_back = base.adjoint(&ref_fwd);
        let mut unsharded_mean = f64::NAN;
        for shards in [1usize, 4] {
            let op = ViewSharded::new(plan.clone(), shards);
            assert_eq!(op.apply(&xin), ref_fwd, "sharded forward must be bit-identical");
            assert_eq!(op.adjoint(&ref_fwd), ref_back, "sharded back must be bit-identical");
            let mut m = bench.run(&format!("op fp+bp 96²/120 sf view-sharded ×{shards}"), || {
                let y = op.apply(&xin);
                leap::bench_harness::black_box(op.adjoint(&y))
            });
            if shards == 1 {
                unsharded_mean = m.mean_s;
            } else {
                let overhead = m.mean_s / unsharded_mean;
                m.notes.push(("sharded_over_unsharded".into(), overhead));
                println!(
                    "    → {shards}-way sharding costs {overhead:.2}× a solo application \
                     (the price of interleavable regions)"
                );
            }
            m.notes.push(("shards".into(), shards as f64));
            m.print();
            all.push(m);
        }
    }

    // ── async serving plane: concurrent v2 sessions on one event loop ──
    // S concurrent sessions (each its own TCP connection) fire R forward
    // requests each at one server. The event loop multiplexes every
    // connection on a single poll thread and the requests share the
    // worker pool, so OS threads stay O(workers + 1) even at 512
    // sessions. Every reply is asserted bit-identical to the in-process
    // plan path. Headline mean_s is the batch wall time; the quantile
    // columns (and the p50/p99 notes) are client-observed per-request
    // latencies across all sessions.
    {
        let conc_vg = VolumeGeometry::slice2d(48, 48, 1.0);
        let conc_g = ParallelBeam::standard_2d(48, 72, 1.0);
        let conc_p =
            Projector::new(Geometry::Parallel(conc_g.clone()), conc_vg.clone(), Model::SF);
        let conc_cfg =
            ScanConfig { geometry: Geometry::Parallel(conc_g.clone()), volume: conc_vg.clone() };
        let conc_vol = vec![0.02f32; conc_vg.num_voxels()];
        let conc_ref = {
            let plan = conc_p.plan();
            let mut vol = conc_p.new_vol();
            vol.data.copy_from_slice(&conc_vol);
            plan.forward(&vol).data
        };
        let conc_backends: Vec<Arc<dyn Executor>> = vec![
            Arc::new(NativeExecutor::new(conc_p.clone())),
            Arc::new(SessionExecutor::new()),
        ];
        let conc_coord = Arc::new(
            Coordinator::new(
                Arc::new(Router::new(conc_backends)),
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                1 << 30,
                4,
            )
            // roomy queue: this sweep measures multiplexing throughput,
            // not shedding (the shed path has its own server tests)
            .with_max_pending(4096),
        );
        let conc_server = Server::start("127.0.0.1:0", conc_coord).expect("bench server");
        let session_counts: &[usize] = if smoke { &[1, 8] } else { &[1, 8, 64, 512] };
        let reqs_per_session = if smoke { 2 } else { 4 };
        for &sessions in session_counts {
            let threads = sessions.min(32);
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for t in 0..threads {
                let addr = conc_server.addr;
                let cfg = conc_cfg.clone();
                let vol = conc_vol.clone();
                let reference = conc_ref.clone();
                // distribute sessions across client threads; each
                // thread runs its share of sessions back-to-back
                let own = sessions / threads + usize::from(t < sessions % threads);
                handles.push(std::thread::spawn(move || {
                    let mut lat = Vec::with_capacity(own * reqs_per_session);
                    for _ in 0..own {
                        let mut client = BinaryClient::connect(&addr).expect("conc client");
                        let session =
                            client.open_session(&cfg, Model::SF, None).expect("conc session");
                        for _ in 0..reqs_per_session {
                            let r0 = std::time::Instant::now();
                            let served = client.forward(session, &vol).expect("conc reply");
                            lat.push(r0.elapsed().as_secs_f64());
                            assert_eq!(
                                served, reference,
                                "concurrent sessions must stay bit-identical"
                            );
                        }
                        client.close_session(session).expect("conc close");
                    }
                    lat
                }));
            }
            let mut lat: Vec<f64> = handles
                .into_iter()
                .flat_map(|h| h.join().expect("conc client thread"))
                .collect();
            let wall = t0.elapsed().as_secs_f64();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = lat.len();
            assert_eq!(n, sessions * reqs_per_session);
            let q = |p: f64| lat[((n as f64 - 1.0) * p).round() as usize];
            let total_reqs = n as f64;
            let mut m = leap::bench_harness::Measurement {
                name: format!("serve v2 ×{sessions} sessions ({reqs_per_session} fp each)"),
                iters: n,
                mean_s: wall,
                median_s: q(0.5),
                p10_s: q(0.1),
                p90_s: q(0.9),
                notes: vec![],
            };
            m.notes.push(("req_per_s".into(), total_reqs / wall));
            m.notes.push(("p50_latency_s".into(), q(0.5)));
            m.notes.push(("p99_latency_s".into(), q(0.99)));
            m.notes.push(("sessions".into(), sessions as f64));
            m.notes.push(("client_threads".into(), threads as f64));
            m.print();
            println!(
                "    → {sessions} concurrent sessions: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
                total_reqs / wall,
                q(0.5) * 1e3,
                q(0.99) * 1e3
            );
            all.push(m);
        }
        drop(conc_server);
    }

    append_results(&all);
    append_results_to(TRAJECTORY, &all);
    println!("appended {} measurements to {TRAJECTORY}", all.len());
}
