//! TABLE 1 reproduction: forward-projection time and memory, on-the-fly
//! LEAP-style projectors vs the stored-system-matrix baseline (the
//! approach the paper's intro argues against).
//!
//! Paper grid (P100 GPU): parallel & cone, 512³/180 and 1024³/720.
//! CPU-feasible grid here: parallel & cone at 64³/90 (default) and
//! 96³/180 (`-- --full`), plus a 2-D 256²/180 row where the CSR baseline
//! fits RAM. The *shape* to reproduce: on-the-fly time is in the same
//! class as any other compute-bound implementation while memory stays at
//! one copy of volume + projections; the stored matrix pays O(nnz) memory
//! — orders of magnitude more — plus a large build cost.
//!
//! Run: `cargo bench --bench table1` (add `-- --full` for the big rows).

use leap::bench_harness::{append_results, Bench};
use leap::geometry::{ConeBeam, Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics::one_copy_bytes;
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::sysmatrix::SystemMatrix;

struct Case {
    name: &'static str,
    geom: Geometry,
    vg: VolumeGeometry,
    /// build the CSR baseline too (skipped where nnz would blow RAM)
    with_matrix: bool,
}

fn cases(full: bool) -> Vec<Case> {
    let mut out = vec![
        Case {
            name: "parallel 64³/90",
            geom: Geometry::Parallel(ParallelBeam::standard_3d(90, 64, 96, 1.0, 1.0)),
            vg: VolumeGeometry::cube(64, 1.0),
            with_matrix: false,
        },
        Case {
            name: "cone 64³/90",
            geom: Geometry::Cone(ConeBeam::standard(90, 80, 96, 1.0, 1.0, 128.0, 256.0)),
            vg: VolumeGeometry::cube(64, 1.0),
            with_matrix: false,
        },
        Case {
            name: "parallel 256²/180 (2-D row)",
            geom: Geometry::Parallel(ParallelBeam::standard_2d(180, 384, 1.0)),
            vg: VolumeGeometry::slice2d(256, 256, 1.0),
            with_matrix: true,
        },
    ];
    if full {
        out.push(Case {
            name: "parallel 96³/180",
            geom: Geometry::Parallel(ParallelBeam::standard_3d(180, 96, 144, 1.0, 1.0)),
            vg: VolumeGeometry::cube(96, 1.0),
            with_matrix: false,
        });
        out.push(Case {
            name: "cone 96³/180",
            geom: Geometry::Cone(ConeBeam::standard(180, 120, 144, 1.0, 1.0, 192.0, 384.0)),
            vg: VolumeGeometry::cube(96, 1.0),
            with_matrix: false,
        });
    }
    out
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let bench = Bench::quick();
    let mut all = Vec::new();
    println!("── Table 1: forward/back projection time (s) and memory ──");
    println!("(paper shape: on-the-fly compute at one-copy memory; stored matrix = O(nnz) memory)\n");
    for case in cases(full) {
        let phantom = if case.vg.nz > 1 {
            shepp::shepp_logan_3d(0.42 * case.vg.nx as f64, 0.02)
        } else {
            shepp::shepp_logan_2d(0.42 * case.vg.nx as f64, 0.02)
        };
        let vol = phantom.rasterize(&case.vg, 1);
        let one_copy = {
            let p = Projector::new(case.geom.clone(), case.vg.clone(), Model::SF);
            one_copy_bytes(vol.len(), p.new_sino().len())
        };
        println!("{}  (one-copy memory {:.1} MB)", case.name, one_copy as f64 / 1e6);

        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(case.geom.clone(), case.vg.clone(), model);
            let mut m =
                bench.run(&format!("{} fwd {}", case.name, model.name()), || p.forward(&vol));
            let rays = p.new_sino().len() as f64;
            m.notes.push(("mem_bytes".into(), one_copy as f64));
            m.notes.push(("rays_per_s".into(), rays / m.mean_s));
            m.print();
            // matched backprojection (the other half of each Table-1 cell)
            let sino = p.forward(&vol);
            let mb =
                bench.run(&format!("{} back {}", case.name, model.name()), || p.back(&sino));
            mb.print();
            all.push(m);
            all.push(mb);
        }

        // plan/execute: amortized A / Aᵀ cost with per-view invariants
        // cached once (what iterative solvers and the coordinator pay)
        {
            let p = Projector::new(case.geom.clone(), case.vg.clone(), Model::SF);
            let plan = p.plan();
            let mut m = bench.run(&format!("{} fwd sf (plan reuse)", case.name), || {
                let mut s = p.new_sino();
                p.forward_with_plan(&plan, &vol, &mut s);
                s
            });
            let rays = p.new_sino().len() as f64;
            m.notes.push(("rays_per_s".into(), rays / m.mean_s));
            m.print();
            let sino = plan.forward(&vol);
            let mb = bench.run(&format!("{} back sf (plan reuse)", case.name), || {
                let mut v = p.new_vol();
                p.back_with_plan(&plan, &sino, &mut v);
                v
            });
            mb.print();
            all.push(m);
            all.push(mb);
        }

        if case.with_matrix {
            // stored-matrix baseline (Lahiri-style): build cost + memory +
            // fetch-bound SpMV apply
            let p = Projector::new(case.geom.clone(), case.vg.clone(), Model::SF).with_threads(1);
            let t0 = std::time::Instant::now();
            let mat = SystemMatrix::build(&p);
            let build_s = t0.elapsed().as_secs_f64();
            let mut m =
                bench.run(&format!("{} fwd stored-matrix", case.name), || mat.forward(&vol));
            m.notes.push(("mem_bytes".into(), mat.nbytes() as f64));
            m.notes.push(("build_s".into(), build_s));
            m.notes
                .push(("mem_ratio_vs_one_copy".into(), mat.nbytes() as f64 / one_copy as f64));
            m.print();
            println!(
                "    → stored matrix: {:.1} MB ({}x one-copy), {:.2}s to build",
                mat.nbytes() as f64 / 1e6,
                mat.nbytes() / one_copy.max(1),
                build_s
            );
            all.push(m);
        }
        println!();
    }
    // paper's 512³/1024³ cells: memory-model extrapolation (the claim is
    // exactly "enough to hold one copy of projections + volume")
    println!("memory-model extrapolation to the paper's grid:");
    for (name, nvox, nproj) in [
        ("512³/180 parallel", 512usize.pow(3), 180 * 512 * 512),
        ("1024³/720 parallel", 1024usize.pow(3), 720 * 1024 * 1024),
        ("512³/180 cone", 512usize.pow(3), 180 * 512 * 512),
        ("1024³/720 cone", 1024usize.pow(3), 720 * 1024 * 1024),
    ] {
        println!(
            "  {name}: one-copy {:.2} GB (paper reports 1.5–11.1 GB incl. transfer buffers)",
            one_copy_bytes(nvox, nproj) as f64 / (1u64 << 30) as f64
        );
    }
    append_results(&all);
}
