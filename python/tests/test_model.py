"""L2 model tests: differentiability (custom_vjp = matched transpose),
FBP quality, data-consistency refinement behaviour, shapes."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def angles_for(nviews, arc=180.0):
    return tuple(math.radians(arc * i / nviews) for i in range(nviews))


def disk_phantom(n, r_frac=0.35, mu=0.02):
    ax = np.arange(n) - (n - 1) / 2.0
    xx, yy = np.meshgrid(ax, ax)
    img = ((xx**2 + yy**2) <= (r_frac * n) ** 2).astype(np.float32) * mu
    return jnp.asarray(img)


def test_project_grad_is_matched_transpose():
    n, nviews, ncols = 16, 8, 24
    angles = angles_for(nviews)
    rng = np.random.default_rng(0)
    vol = jnp.asarray(rng.uniform(0, 1, (n, n)).astype(np.float32))
    y = jnp.asarray(rng.uniform(0, 1, (nviews, ncols)).astype(np.float32))

    def loss(v):
        r = model.xray_project(v, angles, ncols, 1.0, 1.0, "sf") - y
        return 0.5 * jnp.sum(r * r)

    g = jax.grad(loss)(vol)
    # analytic gradient: A^T (A v - y) via the oracle
    av = ref.fp_ref(vol, angles, ncols, model="sf")
    want = ref.bp_ref(np.asarray(av) - np.asarray(y), angles, n, model="sf")
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_project_grad_vs_numerical():
    n, nviews, ncols = 8, 4, 12
    angles = angles_for(nviews)
    rng = np.random.default_rng(1)
    vol = jnp.asarray(rng.uniform(0, 1, (n, n)).astype(np.float32))
    y = jnp.asarray(rng.uniform(0, 1, (nviews, ncols)).astype(np.float32))

    def loss(v):
        r = model.xray_project(v, angles, ncols, 1.0, 1.0, "joseph") - y
        return 0.5 * jnp.sum(r * r)

    g = np.asarray(jax.grad(loss)(vol))
    eps = 1e-2
    for (i, j) in [(2, 3), (5, 5), (0, 7)]:
        vp = vol.at[i, j].add(eps)
        vm = vol.at[i, j].add(-eps)
        num = (float(loss(vp)) - float(loss(vm))) / (2 * eps)
        assert abs(num - g[i, j]) < 5e-2 * max(abs(num), 1.0), f"({i},{j}): {num} vs {g[i, j]}"


def test_backproject_grad_is_forward():
    n, nviews, ncols = 12, 6, 18
    angles = angles_for(nviews)
    rng = np.random.default_rng(2)
    sino = jnp.asarray(rng.uniform(0, 1, (nviews, ncols)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, (n, n)).astype(np.float32))

    def f(s):
        return jnp.sum(model.xray_backproject(s, angles, n, 1.0, 1.0, "sf") * w)

    g = np.asarray(jax.grad(f)(sino))
    want = np.asarray(ref.fp_ref(w, angles, ncols, model="sf"))
    np.testing.assert_allclose(g, want, atol=2e-3, rtol=2e-3)


def test_fbp_recovers_disk():
    n, nviews, ncols = 48, 60, 72
    angles = angles_for(nviews)
    mu = 0.02
    truth = disk_phantom(n, 0.3, mu)
    sino = model.xray_project(truth, angles, ncols, 1.0, 1.0, "sf")
    rec = model.fbp(sino, angles, n)
    center = float(rec[n // 2, n // 2])
    assert abs(center - mu) < 0.2 * mu, f"center {center} vs {mu}"
    corner = float(jnp.abs(rec[2, 2]))
    assert corner < 0.15 * mu


def test_sirt_reduces_residual():
    n, nviews, ncols = 24, 16, 36
    angles = angles_for(nviews)
    truth = disk_phantom(n)
    y = model.xray_project(truth, angles, ncols, 1.0, 1.0, "sf")
    mask = jnp.ones((nviews,), jnp.float32)
    x0 = jnp.zeros((n, n), jnp.float32)

    def resid(x):
        return float(jnp.linalg.norm(model.xray_project(x, angles, ncols, 1.0, 1.0, "sf") - y))

    x5 = model.sirt_steps(x0, y, mask, angles, ncols, iters=5)
    x20 = model.sirt_steps(x0, y, mask, angles, ncols, iters=20)
    assert resid(x5) < resid(x0)
    assert resid(x20) < resid(x5)


def test_dc_refine_improves_imperfect_prior():
    # the Figure-3 shape at L2: prediction + refinement -> higher PSNR
    n, nviews, ncols = 32, 24, 48
    angles = angles_for(nviews)
    truth = disk_phantom(n)
    y = model.xray_project(truth, angles, ncols, 1.0, 1.0, "sf")
    mask = jnp.asarray([1.0 if v < 8 else 0.0 for v in range(nviews)], jnp.float32)  # 60 deg
    pred = truth * 0.85  # imperfect inference output
    refined = model.dc_refine(pred, y, mask, angles, ncols, iters=25)

    def psnr(img):
        mse = float(jnp.mean((img - truth) ** 2))
        return 10 * math.log10(float(jnp.max(truth)) ** 2 / mse)

    assert psnr(refined) > psnr(pred) + 1.0


def test_complete_sinogram_splices():
    n, nviews, ncols = 16, 8, 24
    angles = angles_for(nviews)
    truth = disk_phantom(n)
    y = model.xray_project(truth, angles, ncols, 1.0, 1.0, "sf")
    mask = jnp.asarray([1, 1, 1, 0, 0, 0, 0, 0], jnp.float32)
    pred = truth * 0.5
    completed = model.complete_sinogram(y, mask, pred, angles, ncols)
    np.testing.assert_allclose(np.asarray(completed[:3]), np.asarray(y[:3]))
    pred_sino = model.xray_project(pred, angles, ncols, 1.0, 1.0, "sf")
    np.testing.assert_allclose(np.asarray(completed[3:]), np.asarray(pred_sino[3:]))


def test_prior_denoise_smooths():
    rng = np.random.default_rng(5)
    clean = disk_phantom(32)
    noisy = clean + jnp.asarray(rng.normal(0, 0.004, (32, 32)).astype(np.float32))
    den = model.prior_denoise(noisy)
    e_noisy = float(jnp.mean((noisy - clean) ** 2))
    e_den = float(jnp.mean((den - clean) ** 2))
    assert e_den < e_noisy
    assert float(jnp.min(den)) >= 0.0


def test_dc_loss_masked_views_do_not_contribute():
    n, nviews, ncols = 12, 6, 18
    angles = angles_for(nviews)
    rng = np.random.default_rng(6)
    vol = jnp.asarray(rng.uniform(0, 1, (n, n)).astype(np.float32))
    y = jnp.asarray(rng.uniform(0, 1, (nviews, ncols)).astype(np.float32))
    mask = jnp.asarray([1, 0, 1, 0, 1, 0], jnp.float32)
    base = float(model.data_consistency_loss(vol, y, mask, angles, ncols))
    y_bad = y.at[1].set(999.0).at[3].set(-999.0)
    perturbed = float(model.data_consistency_loss(vol, y_bad, mask, angles, ncols))
    assert base == pytest.approx(perturbed, rel=1e-6)
