"""AOT lowering tests: every entry point produces parseable HLO text with
the manifest describing its shapes; the HLO mentions no Python/Mosaic
custom calls (CPU-PJRT executable)."""

import json
import pathlib
import tempfile

import pytest

from compile import aot, config

# tiny spec so the whole artifact set lowers in seconds
TINY = config.ScanSpec(n=16, nviews=8, ncols=24)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), TINY)
    return out


def test_manifest_lists_all_entries(built):
    manifest = json.loads((built / "manifest.json").read_text())
    names = set(manifest["entries"])
    assert names == set(aot.entry_points(TINY))
    assert manifest["spec"]["n"] == 16


def test_hlo_files_exist_and_are_hlo_text(built):
    manifest = json.loads((built / "manifest.json").read_text())
    for name, entry in manifest["entries"].items():
        text = (built / entry["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_no_mosaic_custom_calls(built):
    # interpret=True must lower pallas into plain HLO ops
    for f in built.glob("*.hlo.txt"):
        text = f.read_text()
        assert "tpu_custom_call" not in text, f.name
        assert "mosaic" not in text.lower(), f.name


def test_no_elided_constants(built):
    # the default HLO printer shortens dense constants to "{...}", which
    # the text parser reads back as zeros — every baked angle table would
    # silently vanish. aot.to_hlo_text must print full constants.
    for f in built.glob("*.hlo.txt"):
        assert "{...}" not in f.read_text(), f"{f.name} has elided constants"


def test_shapes_recorded(built):
    manifest = json.loads((built / "manifest.json").read_text())
    e = manifest["entries"]["fp_sf"]
    assert e["inputs"] == [[16, 16]]
    assert e["outputs"] == [[8, 24]]
    e = manifest["entries"]["dc_refine"]
    assert e["inputs"] == [[16, 16], [8, 24], [8]]
    assert e["outputs"] == [[16, 16]]


def test_executables_run_via_jax_roundtrip(built):
    """Compile the emitted HLO text back through XLA and execute — the
    same path the rust runtime takes (text -> parse -> compile -> run)."""
    import numpy as np
    from jax._src.lib import xla_client as xc

    client = xc._xla.get_local_backend() if hasattr(xc._xla, "get_local_backend") else None
    if client is None:
        pytest.skip("no local backend accessor in this jax version")
    text = (built / "fp_sf.hlo.txt").read_text()
    comp = xc.XlaComputation(xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto()) \
        if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        pytest.skip("hlo_module_from_text unavailable; rust runtime covers this path")
