"""L1 kernel correctness: Pallas vs the pure-jnp dense-matrix oracle.

These are the CORE correctness signals for the compiled artifacts: if the
kernels match ref.py and the adjoint identity holds, the rust side inherits
correctness through the AOT path.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, joseph, ref, sf

MODS = {"joseph": joseph, "sf": sf}


def angles_for(nviews, arc_deg=180.0, start=0.0):
    return [math.radians(start + arc_deg * i / nviews) for i in range(nviews)]


def rand_vol(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (n, n)).astype(np.float32))


def rand_sino(nviews, ncols, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (nviews, ncols)).astype(np.float32))


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_fp_matches_ref(model):
    n, nviews, ncols = 32, 12, 48
    angles = angles_for(nviews)
    vol = rand_vol(n)
    got = np.asarray(MODS[model].fp(vol, angles, ncols))
    want = np.asarray(ref.fp_ref(vol, angles, ncols, model=model))
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_bp_matches_ref(model):
    n, nviews, ncols = 32, 12, 48
    angles = angles_for(nviews)
    sino = rand_sino(nviews, ncols)
    got = np.asarray(MODS[model].bp(sino, angles, n))
    want = np.asarray(ref.bp_ref(sino, angles, n, model=model))
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_adjoint_identity(model):
    n, nviews, ncols = 24, 10, 36
    angles = angles_for(nviews)
    x = rand_vol(n, 3)
    y = rand_sino(nviews, ncols, 4)
    lhs = float(jnp.sum(MODS[model].fp(x, angles, ncols) * y))
    rhs = float(jnp.sum(x * MODS[model].bp(y, angles, n)))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-9) < 1e-4


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_axis_aligned_projection_exact(model):
    # phi = 0: rays along +y, projection of column sums * voxel
    n, ncols = 16, 16
    vol = rand_vol(n, 7)
    got = np.asarray(MODS[model].fp(vol, [0.0], ncols))[0]
    want = np.asarray(vol).sum(axis=0)  # sum over j (rows = y)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_90deg_projection_exact(model):
    # phi = 90: rays along -x, projection of row sums
    n, ncols = 16, 16
    vol = rand_vol(n, 8)
    got = np.asarray(MODS[model].fp(vol, [math.pi / 2], ncols))[0]
    want = np.asarray(vol).sum(axis=1)  # sum over i (cols = x)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_sf_mass_conservation_every_angle():
    # sum over a wide detector * du == sum(vol) * voxel^2 at any angle
    n, ncols = 20, 64
    vol = rand_vol(n, 9)
    for deg in [0, 13, 45, 77, 90, 120, 179]:
        sino = np.asarray(sf.fp(vol, [math.radians(deg)], ncols))
        mass = sino.sum() * 1.0
        want = float(np.asarray(vol).sum())
        assert abs(mass - want) / want < 1e-3, f"angle {deg}: {mass} vs {want}"


def test_split_views_partition():
    angles = angles_for(16, 180.0)
    ia, ib, pa, pb = common.split_views(angles)
    assert sorted(ia + ib) == list(range(16))
    # group A effective |cos| >= |sin|
    for c, s in pa:
        assert abs(c) >= abs(s) - 1e-9
    for c, s in pb:
        assert abs(c) >= abs(s) - 1e-9


@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([8, 16, 24]),
    nviews=st.integers(min_value=1, max_value=9),
    ncols_extra=st.sampled_from([0, 7, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
    model=st.sampled_from(["joseph", "sf"]),
)
def test_hypothesis_fp_bp_match_ref(n, nviews, ncols_extra, seed, model):
    """Property sweep: kernel == oracle across shapes/angle counts/seeds."""
    ncols = n + ncols_extra
    angles = angles_for(nviews, 180.0, start=float(seed % 90))
    vol = rand_vol(n, seed)
    got = np.asarray(MODS[model].fp(vol, angles, ncols))
    want = np.asarray(ref.fp_ref(vol, angles, ncols, model=model))
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
    sino = rand_sino(nviews, ncols, seed + 1)
    gotb = np.asarray(MODS[model].bp(sino, angles, n))
    wantb = np.asarray(ref.bp_ref(sino, angles, n, model=model))
    np.testing.assert_allclose(gotb, wantb, atol=1e-3, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    du_scale=st.sampled_from([0.75, 1.0, 1.5, 2.0]),
    model=st.sampled_from(["joseph", "sf"]),
)
def test_hypothesis_detector_pitch(du_scale, model):
    """Pitch sweep: quantitative scaling holds for du != voxel.

    (du >= voxel is the documented support window of the gather kernels;
    du < voxel=0.75 exercises the margin tap.)"""
    n, nviews = 16, 6
    ncols = int(n * 2 / du_scale)
    angles = angles_for(nviews)
    vol = rand_vol(n, 11)
    got = np.asarray(MODS[model].fp(vol, angles, ncols, 1.0, du_scale))
    want = np.asarray(ref.fp_ref(vol, angles, ncols, 1.0, du_scale, model=model))
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)
