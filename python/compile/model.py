"""L2: the differentiable CT compute graphs (JAX), calling the L1 kernels.

This is the paper's core API surface expressed in JAX instead of PyTorch:

* :func:`xray_project` / :func:`xray_backproject` — differentiable forward
  and back projection. ``custom_vjp`` wires the *matched transpose* as the
  gradient, exactly the paper's `Projector(torch.nn.Module)` contract:
  ``grad ||A x - y||^2 = A^T (A x - y)`` flows through the L1 kernels.
* :func:`fbp` — filtered backprojection graph (ramp filter + matched BP
  with the mass-conservation scale), the classic ill-posed input generator.
* :func:`sirt_steps` / :func:`dc_refine` — iterative data-consistency
  refinement (paper section 3-4) as a single fused ``lax.fori_loop`` graph.
* :func:`prior_denoise` — a small fixed-weight convolutional prior standing
  in for the trained CT-Net+U-Net of the Figure-3 experiment (DESIGN.md
  section 6 documents the substitution).

Every public entry point here is lowered to HLO text by ``aot.py`` and
executed from the rust coordinator — Python never runs at serving time.
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import joseph, sf


def _kernel(model):
    if model == "joseph":
        return joseph
    if model == "sf":
        return sf
    raise ValueError(f"unknown model {model}")


# ---------------------------------------------------------------------------
# differentiable projection (custom_vjp: bwd = matched transpose)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def xray_project(vol, angles, ncols, voxel=1.0, du=1.0, model="joseph"):
    """Differentiable forward projection A x (vol (n,n) -> (nviews,ncols))."""
    return _kernel(model).fp(vol, angles, ncols, voxel, du)


def _fp_fwd(vol, angles, ncols, voxel, du, model):
    return xray_project(vol, angles, ncols, voxel, du, model), vol.shape[0]


def _fp_bwd(angles, ncols, voxel, du, model, n, g):
    # the matched transpose is the exact VJP of a linear operator
    return (_kernel(model).bp(g, angles, n, voxel, du),)


xray_project.defvjp(_fp_fwd, _fp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def xray_backproject(sino, angles, n, voxel=1.0, du=1.0, model="joseph"):
    """Differentiable matched backprojection A^T y ((nviews,ncols) -> (n,n))."""
    return _kernel(model).bp(sino, angles, n, voxel, du)


def _bp_fwd(sino, angles, n, voxel, du, model):
    return xray_backproject(sino, angles, n, voxel, du, model), sino.shape[1]


def _bp_bwd(angles, n, voxel, du, model, ncols, g):
    return (_kernel(model).fp(g, angles, ncols, voxel, du),)


xray_backproject.defvjp(_bp_fwd, _bp_bwd)


# ---------------------------------------------------------------------------
# FBP graph
# ---------------------------------------------------------------------------


def ramp_filter(sino, du=1.0):
    """Band-limited (Kak-Slaney) ramp filter along detector rows."""
    nviews, ncols = sino.shape
    nfft = 1 << int(math.ceil(math.log2(2 * ncols)))
    k = np.zeros(nfft, dtype=np.float64)
    k[0] = 1.0 / (4.0 * du * du)
    odd = np.arange(1, ncols, 2)
    k[odd] = -1.0 / (np.pi**2 * odd.astype(np.float64) ** 2 * du * du)
    k[nfft - odd] = k[odd]
    resp = np.maximum(np.real(np.fft.fft(k)), 0.0) * du  # baked constant
    f = jnp.fft.rfft(sino, n=nfft, axis=1) * jnp.asarray(resp[: nfft // 2 + 1])
    out = jnp.fft.irfft(f, n=nfft, axis=1)[:, :ncols]
    return out.astype(jnp.float32)


def fbp(sino, angles, n, voxel=1.0, du=1.0):
    """Parallel-beam FBP using the matched SF backprojector.

    The SF adjoint deposits ~voxel^2/du of weight per view per voxel, so
    the classic continuous FBP scale dphi becomes dphi*du/voxel^2 (see
    rust/src/recon/fbp.rs for the same calibration).
    """
    filtered = ramp_filter(sino, du)
    dphi = math.pi / len(angles)
    scale = dphi * du / (voxel * voxel)
    return xray_backproject(filtered, angles, n, voxel, du, "sf") * scale


# ---------------------------------------------------------------------------
# iterative data consistency (paper section 3-4)
# ---------------------------------------------------------------------------


def sirt_steps(x0, y, view_mask, angles, ncols, voxel=1.0, du=1.0, iters=20, lam=0.9, model="sf"):
    """`iters` SIRT updates restricted to measured views (mask 1/0).

    x <- x + lam * Dv * A^T(M * Dr * (y - A x)), nonneg-clamped; a single
    fused graph (lax.fori_loop), the dc-refinement hot loop.
    """
    n = x0.shape[0]
    k = _kernel(model)
    mask = view_mask[:, None]  # (nviews, 1)
    ones_vol = jnp.ones((n, n), jnp.float32)
    row_sum = k.fp(ones_vol, angles, ncols, voxel, du)
    inv_row = jnp.where(row_sum > 1e-6, 1.0 / row_sum, 0.0) * mask
    ones_sino = jnp.ones((len(angles), ncols), jnp.float32) * mask
    col_sum = k.bp(ones_sino, angles, n, voxel, du)
    inv_col = jnp.where(col_sum > 1e-6, 1.0 / col_sum, 0.0)

    def body(_, x):
        r = (y - k.fp(x, angles, ncols, voxel, du)) * inv_row
        x = x + lam * inv_col * k.bp(r, angles, n, voxel, du)
        return jnp.maximum(x, 0.0)

    return jax.lax.fori_loop(0, iters, body, x0)


def dc_refine(x_pred, y, view_mask, angles, ncols, voxel=1.0, du=1.0, iters=20, lam=0.9):
    """The paper's inference-time refinement: start from the predicted
    image and enforce consistency with the measured projections."""
    return sirt_steps(x_pred, y, view_mask, angles, ncols, voxel, du, iters, lam, "sf")


def complete_sinogram(y, view_mask, x_pred, angles, ncols, voxel=1.0, du=1.0):
    """Sinogram completion (Anirudh et al. 2018): measured views from y,
    missing views from A x_pred."""
    pred = sf.fp(x_pred, angles, ncols, voxel, du)
    m = view_mask[:, None]
    return y * m + pred * (1.0 - m)


def data_consistency_loss(vol, y, view_mask, angles, ncols, voxel=1.0, du=1.0, model="sf"):
    """``argmin_x ||A x - y||^2`` of the paper section 3, masked; this is the
    differentiable training-loss building block (Figure 2)."""
    r = (xray_project(vol, angles, ncols, voxel, du, model) - y) * view_mask[:, None]
    return 0.5 * jnp.sum(r * r)


# ---------------------------------------------------------------------------
# fixed-weight convolutional prior (inference-model stand-in)
# ---------------------------------------------------------------------------


def _gauss_kernel(sigma, radius):
    ax = np.arange(-radius, radius + 1, dtype=np.float64)
    g = np.exp(-(ax**2) / (2 * sigma * sigma))
    g /= g.sum()
    return g


def prior_denoise(img, strength=0.6):
    """Edge-preserving smoothing prior: a gaussian blur blended with the
    input plus a mild sharpening residual — a deterministic stand-in for
    the trained U-Net denoiser of the Figure-3 pipeline (DESIGN.md sec. 6).

    Lowered as its own artifact so the rust coordinator can apply the
    "inference model" on the request path.
    """
    g = _gauss_kernel(1.2, 3)
    kern = jnp.asarray(np.outer(g, g), dtype=jnp.float32)[None, None]
    x = img[None, None, :, :]
    pad = 3
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)), mode="edge")
    blur = jax.lax.conv_general_dilated(xp, kern, (1, 1), "VALID")[0, 0]
    out = (1.0 - strength) * img + strength * blur
    return jnp.maximum(out, 0.0)
