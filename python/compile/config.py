"""Artifact shape configuration shared by the L1 kernels, the L2 model and
``aot.py``.

Shapes are static at lowering time (one compiled executable per variant,
like the paper's per-geometry CUDA kernels). The default variant matches
the Figure-3 experiment scaled to CPU: 128^2 image, 180 views over 180
degrees, 192 detector columns at 1 mm pitch with 1 mm voxels.

The rust coordinator reads ``artifacts/manifest.json`` (written by aot.py)
to learn each executable's shapes.
"""

from dataclasses import dataclass, field
import math


@dataclass(frozen=True)
class ScanSpec:
    """2-D parallel-beam scan description (mm units, like the rust side)."""

    n: int = 128          # image is n x n
    nviews: int = 180
    ncols: int = 192
    voxel: float = 1.0    # mm
    du: float = 1.0       # mm
    arc_deg: float = 180.0

    @property
    def angles(self):
        return [math.radians(self.arc_deg * i / self.nviews) for i in range(self.nviews)]


# the artifact set built by `make artifacts`
DEFAULT = ScanSpec()
SMALL = ScanSpec(n=64, nviews=90, ncols=96)   # fast tests / CI

# SIRT steps baked into the dc_refine artifact (static loop bound)
DC_REFINE_ITERS = 20
SIRT_LAMBDA = 0.9
