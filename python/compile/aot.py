"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the rust
runtime (`rust/src/runtime`). Run once by `make artifacts`; Python never
touches the request path.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts          # default 128^2 set
    python -m compile.aot --out ../artifacts --small  # 64^2 test set
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides
    # dense array constants as "{...}", which the HLO text parser then
    # reads back as ZEROS (baked view-angle tables, ramp responses and
    # conv kernels silently vanish). See python/tests/test_aot.py.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points(s: config.ScanSpec):
    """Name -> (callable, [input ShapeDtypeStructs]). All shapes static."""
    n, nv, nc = s.n, s.nviews, s.ncols
    angles = s.angles
    vol = _spec((n, n))
    sino = _spec((nv, nc))
    mask = _spec((nv,))

    return {
        "fp_sf": (lambda v: (model.xray_project(v, tuple(angles), nc, s.voxel, s.du, "sf"),), [vol]),
        "bp_sf": (lambda y: (model.xray_backproject(y, tuple(angles), n, s.voxel, s.du, "sf"),), [sino]),
        "fp_joseph": (
            lambda v: (model.xray_project(v, tuple(angles), nc, s.voxel, s.du, "joseph"),),
            [vol],
        ),
        "bp_joseph": (
            lambda y: (model.xray_backproject(y, tuple(angles), n, s.voxel, s.du, "joseph"),),
            [sino],
        ),
        "fbp": (lambda y: (model.fbp(y, tuple(angles), n, s.voxel, s.du),), [sino]),
        "dc_refine": (
            lambda xp, y, m: (
                model.dc_refine(
                    xp, y, m, tuple(angles), nc, s.voxel, s.du,
                    iters=config.DC_REFINE_ITERS, lam=config.SIRT_LAMBDA,
                ),
            ),
            [vol, sino, mask],
        ),
        "complete_sinogram": (
            lambda y, m, xp: (model.complete_sinogram(y, m, xp, tuple(angles), nc, s.voxel, s.du),),
            [sino, mask, vol],
        ),
        "prior_denoise": (lambda v: (model.prior_denoise(v),), [vol]),
        "dc_loss_grad": (
            # value+grad of the paper's data-consistency training loss —
            # proves the custom_vjp path lowers into the same artifact set
            lambda v, y, m: jax.value_and_grad(
                lambda vv: model.data_consistency_loss(vv, y, m, tuple(angles), nc, s.voxel, s.du)
            )(v),
            [vol, sino, mask],
        ),
    }


def build(out_dir: str, spec: config.ScanSpec, only=None):
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {
        "spec": {
            "n": spec.n,
            "nviews": spec.nviews,
            "ncols": spec.ncols,
            "voxel": spec.voxel,
            "du": spec.du,
            "arc_deg": spec.arc_deg,
        },
        "entries": {},
    }
    for name, (fn, in_specs) in entry_points(spec).items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        outs = jax.eval_shape(fn, *in_specs)
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [list(t.shape) for t in in_specs],
            "outputs": [list(t.shape) for t in outs],
        }
        print(f"wrote {out / fname} ({len(text)} chars)")
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out / 'manifest.json'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--small", action="store_true", help="64^2 test-sized artifact set")
    ap.add_argument("--only", nargs="*", help="subset of entry points")
    args = ap.parse_args()
    spec = config.SMALL if args.small else config.DEFAULT
    build(args.out, spec, args.only)


if __name__ == "__main__":
    main()
