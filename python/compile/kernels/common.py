"""Shared helpers for the L1 Pallas kernels.

The 2-D parallel-beam kernels process one view per grid step. Views are
split into two groups by major axis (|cos phi| >= |sin phi| marches rows;
otherwise columns): group-B views are evaluated on the *transposed* volume
with the complementary angle phi' = pi/2 - phi, which maps them exactly
onto the group-A code path (see DESIGN.md "Hardware adaptation" - this is
the TPU-friendly replacement for CUDA's per-thread divergence).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU lowering would use the same BlockSpecs.
"""

import math

import jax.numpy as jnp
import numpy as np


def split_views(angles):
    """Partition view indices by major axis.

    Returns (idx_a, idx_b, params_a, params_b) where params rows are
    (cos, sin, step_scale) of the *effective* angle: group B uses
    phi' = pi/2 - phi so that |cos'| >= |sin'| always holds in-kernel.
    """
    idx_a, idx_b = [], []
    rows_a, rows_b = [], []
    for v, phi in enumerate(angles):
        c, s = math.cos(phi), math.sin(phi)
        if abs(c) >= abs(s):
            idx_a.append(v)
            rows_a.append((c, s))
        else:
            idx_b.append(v)
            rows_b.append((s, c))  # cos' = sin, sin' = cos
    pa = np.asarray(rows_a, dtype=np.float32).reshape(-1, 2)
    pb = np.asarray(rows_b, dtype=np.float32).reshape(-1, 2)
    return idx_a, idx_b, pa, pb


def scatter_views(parts_a, parts_b, idx_a, idx_b, nviews):
    """Reassemble per-group view stacks into acquisition order."""
    ncols = (parts_a if len(idx_a) else parts_b).shape[1]
    out = jnp.zeros((nviews, ncols), dtype=jnp.float32)
    if len(idx_a):
        out = out.at[jnp.asarray(idx_a)].set(parts_a)
    if len(idx_b):
        out = out.at[jnp.asarray(idx_b)].set(parts_b)
    return out


def trap_cdf(t, w_small, w_big):
    """Branchless CDF of the unit-area trapezoid box(w_small) (*) box(w_big).

    Matches ref._trap_cdf; used by the SF kernel (jnp version). For
    near-degenerate w_small the finite-difference form
    (Q(t+w/2)-Q(t-w/2))/w cancels catastrophically in f32, so we blend to
    the exact box CDF (the w_small -> 0 limit) below a threshold safely
    above f32 epsilon.
    """
    wb = jnp.maximum(w_big, 1e-12)

    def Q(x):
        xc = jnp.clip(x, -wb / 2.0, wb / 2.0)
        return (xc + wb / 2.0) ** 2 / (2.0 * wb) + jnp.maximum(x - wb / 2.0, 0.0)

    ws = jnp.maximum(w_small, 1e-3)
    trap = (Q(t + ws / 2.0) - Q(t - ws / 2.0)) / ws
    box = jnp.clip(t / wb + 0.5, 0.0, 1.0)
    return jnp.where(w_small < 1e-3, box, trap)
