"""Pure-jnp oracle for the L1 Pallas kernels — the CORE correctness signal.

Builds the *dense* per-view weight matrix for the 2-D parallel-beam Joseph
and Separable-Footprint models and applies it with einsum. Slow (O(V*C*N*N)
work) but transparently correct, and the transpose is the literal matrix
transpose, so matched-pair tests are exact by construction.

Conventions identical to the rust side (rust/src/geometry):
  voxel (i, j) center x = (i - (n-1)/2)*voxel (same for y with j)
  detector col c center u = (c - (ncols-1)/2)*du
  view angle phi: ray direction (-sin phi, cos phi), u axis (cos phi, sin phi)
"""

import jax.numpy as jnp
import numpy as np


def _joseph_view_weights(phi, n, ncols, voxel, du):
    """Dense (ncols, n, n) Joseph weights for one view; indices (c, j, i)."""
    h = (n - 1) / 2.0
    c_idx = np.arange(ncols)
    u = (c_idx - (ncols - 1) / 2.0) * du
    i_idx = np.arange(n)
    j_idx = np.arange(n)
    cphi, sphi = np.cos(phi), np.sin(phi)
    if abs(cphi) >= abs(sphi):
        # major axis y: march rows j, interpolate along x
        step = voxel / abs(cphi)
        # x(u, y) = u/cos - y*tan ; fx = x/voxel + h
        y = (j_idx - h) * voxel  # (n,)
        fx = (u[:, None] / cphi - y[None, :] * (sphi / cphi)) / voxel + h  # (c, j)
        w = np.maximum(0.0, 1.0 - np.abs(fx[:, :, None] - i_idx[None, None, :]))  # (c, j, i)
        return w * step
    else:
        # major axis x: march columns i, interpolate along y
        step = voxel / abs(sphi)
        x = (i_idx - h) * voxel
        fy = (u[:, None] / sphi - x[None, :] * (cphi / sphi)) / voxel + h  # (c, i)
        w = np.maximum(0.0, 1.0 - np.abs(fy[:, :, None] - j_idx[None, None, :]))  # (c, i, j)
        return np.swapaxes(w, 1, 2) * step  # -> (c, j, i)


def _trap_cdf(t, w_small, w_big):
    """CDF of the unit-area trapezoid = box(w_small) (*) box(w_big).

    Q(x) = antiderivative of the big box's CDF; F(t) = (Q(t + w_small/2)
    - Q(t - w_small/2)) / w_small with a stable small-width guard.
    """
    wb = max(w_big, 1e-12)

    def Q(x):
        xc = np.clip(x, -wb / 2.0, wb / 2.0)
        return (xc + wb / 2.0) ** 2 / (2.0 * wb) + np.maximum(x - wb / 2.0, 0.0)

    # same degenerate-width blend as common.trap_cdf (kernel parity)
    if w_small < 1e-3:
        return np.clip(t / wb + 0.5, 0.0, 1.0)
    return (Q(t + w_small / 2.0) - Q(t - w_small / 2.0)) / w_small


def _sf_view_weights(phi, n, ncols, voxel, du):
    """Dense (ncols, n, n) separable-footprint weights for one view."""
    h = (n - 1) / 2.0
    cphi, sphi = np.cos(phi), np.sin(phi)
    w1 = voxel * abs(cphi)
    w2 = voxel * abs(sphi)
    w_small, w_big = min(w1, w2), max(w1, w2)
    amp = voxel * voxel  # footprint area (2-D); unit-area trapezoid below

    i_idx = np.arange(n)
    j_idx = np.arange(n)
    x = (i_idx - h) * voxel
    y = (j_idx - h) * voxel
    uc = x[None, :] * cphi + y[:, None] * sphi  # (j, i) voxel centers on detector
    c_idx = np.arange(ncols)
    u_lo = (c_idx - (ncols - 1) / 2.0) * du - du / 2.0  # (c,)
    t_lo = u_lo[:, None, None] - uc[None, :, :]
    t_hi = t_lo + du
    w = amp * (_trap_cdf(t_hi, w_small, w_big) - _trap_cdf(t_lo, w_small, w_big)) / du
    return w  # (c, j, i)


def _weights(model, phi, n, ncols, voxel, du):
    if model == "joseph":
        return _joseph_view_weights(phi, n, ncols, voxel, du)
    if model == "sf":
        return _sf_view_weights(phi, n, ncols, voxel, du)
    raise ValueError(f"unknown model {model}")


def fp_ref(vol, angles, ncols, voxel=1.0, du=1.0, model="joseph"):
    """Forward projection oracle: vol (n, n) -> sino (nviews, ncols)."""
    vol = np.asarray(vol, dtype=np.float64)
    n = vol.shape[0]
    assert vol.shape == (n, n)
    out = np.zeros((len(angles), ncols))
    for v, phi in enumerate(angles):
        w = _weights(model, phi, n, ncols, voxel, du)
        out[v] = np.einsum("cji,ji->c", w, vol)
    return jnp.asarray(out, dtype=jnp.float32)


def bp_ref(sino, angles, n, voxel=1.0, du=1.0, model="joseph"):
    """Matched backprojection oracle: the literal transpose of fp_ref."""
    sino = np.asarray(sino, dtype=np.float64)
    ncols = sino.shape[1]
    out = np.zeros((n, n))
    for v, phi in enumerate(angles):
        w = _weights(model, phi, n, ncols, voxel, du)
        out += np.einsum("cji,c->ji", w, sino[v])
    return jnp.asarray(out, dtype=jnp.float32)


def ramp_filter_ref(sino, du=1.0):
    """Kak-Slaney band-limited ramp filtering of each detector row."""
    sino = np.asarray(sino, dtype=np.float64)
    _nviews, ncols = sino.shape
    nfft = 1 << int(np.ceil(np.log2(2 * ncols)))
    k = np.zeros(nfft)
    k[0] = 1.0 / (4.0 * du * du)
    odd = np.arange(1, ncols, 2)
    k[odd] = -1.0 / (np.pi**2 * odd.astype(np.float64) ** 2 * du * du)
    k[nfft - odd] = k[odd]
    resp = np.real(np.fft.fft(k))
    resp = np.maximum(resp, 0.0) * du
    f = np.fft.fft(sino, n=nfft, axis=1) * resp[None, :]
    out = np.real(np.fft.ifft(f, axis=1))[:, :ncols]
    return jnp.asarray(out, dtype=jnp.float32)
