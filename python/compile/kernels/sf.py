"""L1 Pallas kernels: Separable-Footprint forward/back projection, 2-D
parallel beam (Long, Fessler & Balter 2010) — the paper's most accurate
projector model.

Each voxel's footprint on the detector is the trapezoid
``box(voxel*|cos|) (*) box(voxel*|sin|)``; a detector bin's coefficient is
the *exact* bin integral of that trapezoid (finite voxel AND finite
detector-bin width, unlike Joseph/Siddon point sampling). The bin integral
is evaluated branchlessly via clipped-quadratic CDFs (common.trap_cdf), so
the inner loop is pure VPU arithmetic plus the same regular gathers as the
Joseph kernel — no data-dependent control flow, which is exactly the
rethink a TPU wants instead of CUDA's divergent footprint loops.

Forward gathers voxels into bins through the inverse map i*(c); the
backprojector gathers bins into voxels through the forward map c*(i) with
the identical coefficient formula, so the pair is exactly matched.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

# gather window half-width: footprint (<= voxel*sqrt(2)) plus one bin,
# divided by the index slope (>= 1 for du >= voxel in the major group)
_K = 3


def _coeff(u_bin_center, uc, w1, w2, du):
    """SF coefficient: unit-area trapezoid at uc integrated over the bin."""
    w_small = jnp.minimum(w1, w2)
    w_big = jnp.maximum(w1, w2)
    t_lo = (u_bin_center - du / 2.0) - uc
    f = common.trap_cdf(t_lo + du, w_small, w_big) - common.trap_cdf(t_lo, w_small, w_big)
    return f / du


def _fp_kernel(params_ref, vol_ref, out_ref, *, n, ncols, voxel, du):
    """One view: params (1, 2) = (cos, sin); vol (n, n); out (1, ncols)."""
    cphi = params_ref[0, 0]
    sphi = params_ref[0, 1]
    w1 = voxel * jnp.abs(cphi)
    w2 = voxel * jnp.abs(sphi)
    amp = voxel * voxel
    h = (n - 1) / 2.0
    c = jnp.arange(ncols, dtype=jnp.float32)
    u = (c - (ncols - 1) / 2.0) * du  # bin centers
    vol = vol_ref[...]

    def body(j, acc):
        y = (j.astype(jnp.float32) - h) * voxel
        # voxel index whose center projects onto each bin center
        istar = (u - y * sphi) / (voxel * cphi) + h
        ibase = jnp.floor(istar).astype(jnp.int32)
        row = jax.lax.dynamic_slice_in_dim(vol, j, 1, 0)[0]
        contrib = jnp.zeros((ncols,), jnp.float32)
        for k in range(-_K, _K + 1):
            ik = ibase + k
            xk = (ik.astype(jnp.float32) - h) * voxel
            uc = xk * cphi + y * sphi
            wgt = amp * _coeff(u, uc, w1, w2, du)
            g = jnp.take(row, jnp.clip(ik, 0, n - 1))
            m = ((ik >= 0) & (ik <= n - 1)).astype(jnp.float32)
            contrib = contrib + wgt * g * m
        return acc + contrib

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((ncols,), jnp.float32))
    out_ref[0, :] = acc


def _bp_kernel(params_ref, sino_ref, out_ref, *, n, ncols, voxel, du):
    """One view: accumulate the matched SF transpose into out (n, n)."""
    view = pl.program_id(0)

    @pl.when(view == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cphi = params_ref[0, 0]
    sphi = params_ref[0, 1]
    w1 = voxel * jnp.abs(cphi)
    w2 = voxel * jnp.abs(sphi)
    amp = voxel * voxel
    h = (n - 1) / 2.0
    i_idx = jnp.arange(n, dtype=jnp.float32)
    x = (i_idx - h) * voxel
    srow = sino_ref[0, :]

    def body(j, acc):
        y = (j.astype(jnp.float32) - h) * voxel
        uc = x * cphi + y * sphi  # voxel centers on the detector
        cstar = uc / du + (ncols - 1) / 2.0
        cbase = jnp.floor(cstar).astype(jnp.int32)
        contrib = jnp.zeros((n,), jnp.float32)
        for k in range(-_K, _K + 1):
            ck = cbase + k
            u_k = (ck.astype(jnp.float32) - (ncols - 1) / 2.0) * du
            wgt = amp * _coeff(u_k, uc, w1, w2, du)
            s = jnp.take(srow, jnp.clip(ck, 0, ncols - 1))
            m = ((ck >= 0) & (ck <= ncols - 1)).astype(jnp.float32)
            contrib = contrib + wgt * s * m
        return acc.at[j, :].add(contrib)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((n, n), jnp.float32))
    out_ref[...] += acc


def _fp_group(vol, params, ncols, voxel, du):
    nv = params.shape[0]
    n = vol.shape[0]
    if nv == 0:
        return jnp.zeros((0, ncols), jnp.float32)
    kernel = functools.partial(_fp_kernel, n=n, ncols=ncols, voxel=voxel, du=du)
    return pl.pallas_call(
        kernel,
        grid=(nv,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda v: (v, 0)),
            pl.BlockSpec((n, n), lambda v: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ncols), lambda v: (v, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, ncols), jnp.float32),
        interpret=True,
    )(params, vol)


def _bp_group(sino, params, n, voxel, du):
    nv, ncols = sino.shape
    if nv == 0:
        return jnp.zeros((n, n), jnp.float32)
    kernel = functools.partial(_bp_kernel, n=n, ncols=ncols, voxel=voxel, du=du)
    return pl.pallas_call(
        kernel,
        grid=(nv,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda v: (v, 0)),
            pl.BlockSpec((1, ncols), lambda v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda v: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(params, sino)


def fp(vol, angles, ncols, voxel=1.0, du=1.0):
    """SF forward projection: vol (n, n) -> sino (nviews, ncols)."""
    idx_a, idx_b, pa, pb = common.split_views(angles)
    sino_a = _fp_group(vol, jnp.asarray(pa), ncols, voxel, du)
    sino_b = _fp_group(vol.T, jnp.asarray(pb), ncols, voxel, du)
    return common.scatter_views(sino_a, sino_b, idx_a, idx_b, len(angles))


def bp(sino, angles, n, voxel=1.0, du=1.0):
    """Matched SF backprojection: sino (nviews, ncols) -> vol (n, n)."""
    idx_a, idx_b, pa, pb = common.split_views(angles)
    out = jnp.zeros((n, n), jnp.float32)
    if idx_a:
        out = out + _bp_group(sino[jnp.asarray(idx_a)], jnp.asarray(pa), n, voxel, du)
    if idx_b:
        out = out + _bp_group(sino[jnp.asarray(idx_b)], jnp.asarray(pb), n, voxel, du).T
    return out
