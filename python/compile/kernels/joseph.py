"""L1 Pallas kernels: Joseph-method forward/back projection, 2-D parallel
beam — the paper's compute hot-spot as a TPU-shaped kernel.

Formulation (DESIGN.md "Hardware adaptation"): instead of CUDA's
one-thread-per-ray with texture fetches, each grid step computes one full
view. The inner loop marches image rows; the interpolation is a dense
regular gather over the lane dimension (detector bins), which vectorizes
on the VPU, and the whole volume tile sits in VMEM (128 x 128 f32 = 64 KiB,
double-buffered against HBM by the BlockSpec pipeline on real hardware).

The backprojector enumerates the *identical* weights from the voxel side
(window gather around the inverse map), so the pair is exactly matched -
verified against ref.py's literal matrix transpose in the tests.

VMEM budget (per grid step, default 128^2/180/192 artifact):
  volume 64 KiB + sino row 0.75 KiB + params 8 B  << 16 MiB.
MXU note: the lerp could be phrased as two (n x n)(n x c) matmuls with
banded one-hot weights to target the MXU; on CPU-interpret the gather
formulation is clearer and numerically identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _fp_kernel(params_ref, vol_ref, out_ref, *, n, ncols, voxel, du):
    """One view: params (1, 2) = (cos, sin); vol (n, n); out (1, ncols)."""
    cphi = params_ref[0, 0]
    sphi = params_ref[0, 1]
    inv_cos = 1.0 / cphi
    step = voxel / jnp.abs(cphi)
    h = (n - 1) / 2.0
    c = jnp.arange(ncols, dtype=jnp.float32)
    u = (c - (ncols - 1) / 2.0) * du
    base = u * inv_cos / voxel + h  # fx at y = 0 ... minus the y term below
    vol = vol_ref[...]

    def body(j, acc):
        y = (j.astype(jnp.float32) - h) * voxel
        fx = base - y * (sphi * inv_cos) / voxel
        i0 = jnp.floor(fx)
        w1 = fx - i0
        i0i = i0.astype(jnp.int32)
        row = jax.lax.dynamic_slice_in_dim(vol, j, 1, 0)[0]
        g0 = jnp.take(row, jnp.clip(i0i, 0, n - 1))
        g1 = jnp.take(row, jnp.clip(i0i + 1, 0, n - 1))
        m0 = ((i0i >= 0) & (i0i <= n - 1)).astype(jnp.float32)
        m1 = ((i0i + 1 >= 0) & (i0i + 1 <= n - 1)).astype(jnp.float32)
        return acc + ((1.0 - w1) * g0 * m0 + w1 * g1 * m1) * step

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((ncols,), jnp.float32))
    out_ref[0, :] = acc


def _bp_kernel(params_ref, sino_ref, out_ref, *, n, ncols, voxel, du):
    """One view: accumulate the matched transpose into out (n, n)."""
    view = pl.program_id(0)

    @pl.when(view == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cphi = params_ref[0, 0]
    sphi = params_ref[0, 1]
    inv_cos = 1.0 / cphi
    step = voxel / jnp.abs(cphi)
    h = (n - 1) / 2.0
    i_idx = jnp.arange(n, dtype=jnp.float32)
    x = (i_idx - h) * voxel
    srow = sino_ref[0, :]

    def body(j, acc):
        y = (j.astype(jnp.float32) - h) * voxel
        # detector coordinate of voxel (i, j): u* = x cos + y sin
        cstar = (x * cphi + y * sphi) / du + (ncols - 1) / 2.0
        cbase = jnp.floor(cstar).astype(jnp.int32)
        contrib = jnp.zeros((n,), jnp.float32)
        # the same |fx - i| < 1 support enumerated from the voxel side;
        # |dfx/dc| = du/(voxel |cos|) >= 1 for du >= voxel, so +-2 bins
        # bracket the support (see tests::window_covers_support)
        for k in range(-2, 3):
            ck = cbase + k
            u_k = (ck.astype(jnp.float32) - (ncols - 1) / 2.0) * du
            fx = (u_k * inv_cos - y * (sphi * inv_cos)) / voxel + h
            w = jnp.maximum(0.0, 1.0 - jnp.abs(fx - i_idx)) * step
            s = jnp.take(srow, jnp.clip(ck, 0, ncols - 1))
            m = ((ck >= 0) & (ck <= ncols - 1)).astype(jnp.float32)
            contrib = contrib + w * s * m
        return acc.at[j, :].add(contrib)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((n, n), jnp.float32))
    out_ref[...] += acc


def _fp_group(vol, params, ncols, voxel, du):
    """Forward-project one major-axis group (params (nv, 2))."""
    nv = params.shape[0]
    n = vol.shape[0]
    if nv == 0:
        return jnp.zeros((0, ncols), jnp.float32)
    kernel = functools.partial(_fp_kernel, n=n, ncols=ncols, voxel=voxel, du=du)
    return pl.pallas_call(
        kernel,
        grid=(nv,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda v: (v, 0)),
            pl.BlockSpec((n, n), lambda v: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ncols), lambda v: (v, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, ncols), jnp.float32),
        interpret=True,
    )(params, vol)


def _bp_group(sino, params, n, voxel, du):
    """Backproject one major-axis group (sino (nv, ncols))."""
    nv, ncols = sino.shape
    if nv == 0:
        return jnp.zeros((n, n), jnp.float32)
    kernel = functools.partial(_bp_kernel, n=n, ncols=ncols, voxel=voxel, du=du)
    return pl.pallas_call(
        kernel,
        grid=(nv,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda v: (v, 0)),
            pl.BlockSpec((1, ncols), lambda v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda v: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(params, sino)


def fp(vol, angles, ncols, voxel=1.0, du=1.0):
    """Joseph forward projection: vol (n, n) -> sino (nviews, ncols)."""
    idx_a, idx_b, pa, pb = common.split_views(angles)
    sino_a = _fp_group(vol, jnp.asarray(pa), ncols, voxel, du)
    sino_b = _fp_group(vol.T, jnp.asarray(pb), ncols, voxel, du)
    return common.scatter_views(sino_a, sino_b, idx_a, idx_b, len(angles))


def bp(sino, angles, n, voxel=1.0, du=1.0):
    """Matched Joseph backprojection: sino (nviews, ncols) -> vol (n, n)."""
    idx_a, idx_b, pa, pb = common.split_views(angles)
    out = jnp.zeros((n, n), jnp.float32)
    if idx_a:
        out = out + _bp_group(sino[jnp.asarray(idx_a)], jnp.asarray(pa), n, voxel, du)
    if idx_b:
        out = out + _bp_group(sino[jnp.asarray(idx_b)], jnp.asarray(pb), n, voxel, du).T
    return out
